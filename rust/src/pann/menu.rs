//! The menu compiler: build, Pareto-prune, persist and reload the
//! full power–accuracy frontier (paper Sec. 6, Tables 14–15).
//!
//! The paper's deployment claim is that PANN "seamlessly traverses the
//! power-accuracy trade-off at deployment time" — but traversing needs
//! a *menu*: the set of `(b̃_x, R)` operating points actually worth
//! serving. Following Moons et al. (*Minimum Energy Quantized Neural
//! Networks*, 2017), finding that set requires sweeping the whole
//! precision/energy grid, not guessing 2–3 points by hand:
//!
//! 1. [`sweep_equal_power`] — the one sweep core shared with
//!    Algorithm 1 ([`super::algorithm1`]) and the Table-15 curve
//!    ([`super::tradeoff`]): walk `b̃_x` along an equal-power curve
//!    (`R` from [`crate::power::budget::equal_power_r_usable`]),
//!    compile each candidate ([`QuantizedModel::prepare`]) and measure
//!    validation accuracy + Gflips/sample ([`eval_quantized`]).
//! 2. [`compile_menu`] — run the sweep over one curve per requested
//!    budget bit width, then [`pareto_prune`] the union to the
//!    monotone accuracy-vs-energy frontier (a point survives only if
//!    no cheaper point classifies at least as well).
//! 3. [`MenuArtifact`] — the versioned `menu.json` form of the
//!    frontier (schema [`MENU_SCHEMA`]): model name + fingerprint,
//!    per-point `(name, b̃_x, R, Gflips/sample, val-acc, quantizer)`.
//! 4. [`MenuArtifact::shared_points`] — recompile every persisted
//!    point into an [`ExecutionPlan`]-backed engine for the serving
//!    pool; [`crate::coordinator::Menu::from_artifact`] wraps this so
//!    `pann-cli compile-menu` → `pann-cli serve --menu menu.json`
//!    round-trips.

use crate::coordinator::{PlanEngine, SharedPoint};
use crate::data::Dataset;
use crate::nn::eval::{batch_tensor, eval_quantized};
use crate::nn::quantized::{QuantConfig, QuantizedModel};
use crate::nn::{ExecutionPlan, Model, Tensor};
use crate::power::budget::equal_power_r_usable;
use crate::power::model::{mac_power_unsigned_total, pann_power_per_element};
use crate::quant::ActQuantMethod;
use crate::util::Json;
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Version tag written to new `menu.json` artifacts. The lineage is
/// strictly additive:
///
/// - `v2` added the optional per-point `measured_gflips_per_sample`
///   calibration (fed back via [`MenuArtifact::apply_calibration`],
///   e.g. from `pann-cli serve --menu … --calibrate-out`);
/// - `v3` adds the optional per-point `layer_bits: [b̃x, …]` — a
///   mixed-precision point compiled with one activation width per MAC
///   layer ([`compile_menu_per_layer`]). Points without the field are
///   uniform, exactly as before, and consumers that only read cost and
///   accuracy (server, governor, policy) need no changes.
///
/// The loader accepts all three versions; unknown schemas are rejected
/// instead of misread.
pub const MENU_SCHEMA: &str = "pann-menu/v3";

/// The previous schema, still accepted on read (its points carry no
/// per-layer widths).
pub const MENU_SCHEMA_V2: &str = "pann-menu/v2";

/// The original schema, still accepted on read (its points carry
/// neither calibration nor per-layer widths).
pub const MENU_SCHEMA_V1: &str = "pann-menu/v1";

/// One evaluated candidate from an equal-power sweep.
pub struct SweepPoint {
    /// Activation width `b̃_x`.
    pub bx_tilde: u32,
    /// Requested additions budget `R` (Eq. 13 inversion at the curve's
    /// power level).
    pub r: f64,
    /// Power per element implied by Eq. (13) with the requested `R`
    /// (= the curve's power level).
    pub power_per_element: f64,
    /// Validation accuracy of the compiled candidate.
    pub val_acc: f64,
    /// *Measured* energy per sample in Giga bit flips (metered by the
    /// engine, not the analytic budget).
    pub gflips_per_sample: f64,
    /// Achieved `‖w_q‖₁/d` across MAC layers, MAC-weighted — the
    /// latency factor actually realized (vs the requested `r`).
    pub achieved_adds_per_element: f64,
    /// Storage bits per weight code (`b_R`, Table 14).
    pub weight_code_bits: u32,
}

/// Sweep every usable `b̃_x` on the equal-power curve at `power` flips
/// per element: the shared evaluation core of Algorithm 1, the
/// Table-15 trade-off table and the menu compiler. Candidates whose
/// inverted `R` falls below [`crate::power::budget::MIN_R`] are
/// skipped (the budget cannot afford that activation width).
///
/// Each candidate's compiled plan is dropped after measurement, so
/// peak memory stays at one weight bank regardless of grid size; the
/// menu compiler recompiles only the kept frontier points.
pub fn sweep_equal_power(
    model: &Model,
    power: f64,
    act_method: ActQuantMethod,
    calib: Option<&Tensor>,
    val: &Dataset,
    bx_range: std::ops::RangeInclusive<u32>,
) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::new();
    for bx in bx_range {
        let Some(r) = equal_power_r_usable(power, bx) else {
            continue;
        };
        let cfg = QuantConfig::pann(bx, r, act_method);
        let qm = QuantizedModel::prepare(model, cfg, calib)
            .with_context(|| format!("compile candidate b̃x={bx} R={r:.3}"))?;
        let res = eval_quantized(&qm, val)?;
        out.push(SweepPoint {
            bx_tilde: bx,
            r,
            power_per_element: pann_power_per_element(r, bx),
            val_acc: res.accuracy(),
            gflips_per_sample: res.flips_per_sample / 1e9,
            achieved_adds_per_element: qm.achieved_r(),
            weight_code_bits: qm.weight_code_bits(),
        });
    }
    Ok(out)
}

/// Prune candidates to the monotone accuracy-vs-energy Pareto
/// frontier: sorted by cost, a point survives only if it classifies
/// *strictly* better than every cheaper survivor (equal-accuracy
/// points at higher cost are dominated). The result is strictly
/// increasing in both cost and accuracy, so a budget policy over it
/// can never pick a dominated point.
///
/// Generic over the candidate representation (`cost`/`acc` accessors)
/// so the invariant is property-testable without compiling models.
pub fn pareto_prune<T>(
    mut cands: Vec<T>,
    cost: impl Fn(&T) -> f64,
    acc: impl Fn(&T) -> f64,
) -> Vec<T> {
    // cheapest first; among equal costs, best accuracy first so the
    // weaker same-cost candidates fail the strict-improvement test
    cands.sort_by(|a, b| cost(a).total_cmp(&cost(b)).then(acc(b).total_cmp(&acc(a))));
    let mut kept: Vec<T> = Vec::new();
    for c in cands {
        if kept.last().map_or(true, |l| acc(&c) > acc(l)) {
            kept.push(c);
        }
    }
    kept
}

/// One persisted frontier point of a [`MenuArtifact`].
#[derive(Clone, Debug, PartialEq)]
pub struct MenuPointSpec {
    /// Stable point name (unique within the menu; pinnable via
    /// [`crate::coordinator::InferRequest::pin_point`]).
    pub name: String,
    /// Activation width `b̃_x`.
    pub bx_tilde: u32,
    /// Additions budget `R` the point was compiled at.
    pub r: f64,
    /// Measured energy per sample (Giga bit flips) — the cost the
    /// serving policy ranks by.
    pub gflips_per_sample: f64,
    /// Validation accuracy measured at compile time.
    pub val_acc: f64,
    /// Activation quantizer the point was compiled and measured with.
    pub quant_method: ActQuantMethod,
    /// Achieved additions per element (latency factor, Sec. 6).
    pub achieved_adds_per_element: f64,
    /// Storage bits per weight code (`b_R`).
    pub weight_code_bits: u32,
    /// Serving-side measured-cost calibration (`pann-menu/v2`,
    /// additive): Gflips/sample the deployed engines actually metered
    /// for this point, written back via
    /// [`MenuArtifact::apply_calibration`]. Informational — the
    /// serving policy keeps ranking by the compile-time
    /// `gflips_per_sample`, whose strict monotonicity the loader
    /// enforces; a calibration pass must not be able to reorder or
    /// invalidate the frontier.
    pub measured_gflips_per_sample: Option<f64>,
    /// Mixed-precision points only (`pann-menu/v3`, additive): the
    /// activation width of every MAC layer in graph order, each in
    /// `1..=31`, with `bx_tilde` equal to the widest entry. `None`
    /// means the point is uniform at `bx_tilde`. Recompilation routes
    /// through [`ExecutionPlan::compile_with_layers`], so a mixed
    /// point passes exactly the same per-layer certificate prover as a
    /// uniform one.
    pub layer_bits: Option<Vec<u32>>,
}

/// The versioned, serializable power–accuracy frontier of one model.
///
/// Invariant: `points` is sorted ascending by `gflips_per_sample` and
/// strictly Pareto-monotone (accuracy strictly increasing with cost).
#[derive(Clone, Debug, PartialEq)]
pub struct MenuArtifact {
    /// Name of the model the menu was compiled for.
    pub model_name: String,
    /// [`Model::fingerprint`] of the network the menu was compiled
    /// for; verified again before recompiling for serving.
    pub model_fingerprint: u64,
    /// MACs per sample of that model (plan-consistency check).
    pub macs_per_sample: u64,
    /// Candidates evaluated before Pareto pruning (for reporting:
    /// `swept - points.len()` were dominated).
    pub swept: usize,
    /// The frontier, ascending in cost and accuracy.
    pub points: Vec<MenuPointSpec>,
}

/// Compile the full operating-point menu for `model`: one equal-power
/// sweep per entry of `budget_bits` (the curve matching a `b`-bit
/// unsigned MAC), Pareto-pruned to the frontier.
///
/// ```
/// use pann::data::{synth, Dataset};
/// use pann::nn::Model;
/// use pann::pann::compile_menu;
/// use pann::quant::ActQuantMethod;
///
/// let mut model = Model::reference_cnn(7);
/// let ds = Dataset::from_synth(synth::digits(48, 9));
/// let stats = pann::nn::eval::batch_tensor(&ds, 0, 24);
/// model.record_act_stats(&stats)?;
///
/// let menu = compile_menu(&model, &[2], ActQuantMethod::BnStats, None, &ds.take(32), 2..=4)?;
/// assert!(!menu.points.is_empty());
/// // the frontier is strictly monotone: paying more energy must buy accuracy
/// for w in menu.points.windows(2) {
///     assert!(w[1].gflips_per_sample > w[0].gflips_per_sample);
///     assert!(w[1].val_acc > w[0].val_acc);
/// }
/// # Ok::<(), anyhow::Error>(())
/// ```
///
/// `val` drives the accuracy measurement; `calib` feeds the quantizer
/// methods that need calibration inputs (ACIQ, Recon). The result
/// carries measurements only — serve it via [`MenuArtifact::save`] +
/// [`crate::coordinator::Menu::from_artifact`] (or recompile directly
/// with [`MenuArtifact::shared_points`]); plans are built exactly once
/// at serving time, when the engine batch size is known.
pub fn compile_menu(
    model: &Model,
    budget_bits: &[u32],
    act_method: ActQuantMethod,
    calib: Option<&Tensor>,
    val: &Dataset,
    bx_range: std::ops::RangeInclusive<u32>,
) -> Result<MenuArtifact> {
    let cands = uniform_candidates(model, budget_bits, act_method, calib, val, &bx_range)?;
    anyhow::ensure!(
        !cands.is_empty(),
        "no usable operating point for budgets {budget_bits:?} over b̃x {bx_range:?}"
    );
    Ok(finish_menu(model, act_method, cands))
}

/// Budget knobs for the per-layer mixed-precision search
/// ([`compile_menu_per_layer`]).
#[derive(Clone, Copy, Debug)]
pub struct PerLayerSearch {
    /// Validation samples used by the per-layer sensitivity evals.
    /// Every *emitted* candidate is still scored on the full `val`
    /// set; only the cheap single-layer probes subsample.
    pub sensitivity_samples: usize,
    /// Cap on emitted mixed-precision candidates (the length of the
    /// greedy downgrade ladder).
    pub max_mixed_points: usize,
}

impl Default for PerLayerSearch {
    fn default() -> Self {
        PerLayerSearch { sensitivity_samples: 64, max_mixed_points: 8 }
    }
}

/// [`compile_menu`] plus a sensitivity-guided per-layer search (Moons
/// et al., *Minimum Energy Quantized Neural Networks*: automated
/// per-layer bit-width assignment under an energy objective dominates
/// uniform quantization).
///
/// On top of the uniform sweep, the search
///
/// 1. picks the best-accuracy uniform candidate as the *base* and the
///    narrowest swept width as the downgrade target,
/// 2. runs a **sensitivity pass**: one metered forward collects each
///    layer's energy share (its slice of the per-layer Eq.-13
///    [`crate::nn::PowerMeter`] tally), and one single-layer-downgrade
///    eval per MAC layer measures its accuracy drop,
/// 3. walks a **greedy downgrade ladder** in best
///    Δaccuracy-per-ΔGflips order — cheapest accuracy loss per energy
///    saved first — emitting one mixed-precision candidate per step,
///    each compiled via [`ExecutionPlan::compile_with_layers`] and
///    scored on the full `val` set,
/// 4. merges uniform and mixed candidates through the same
///    [`pareto_prune`].
///
/// Because the merged frontier is pruned over the *union* of
/// candidates, every uniform frontier point is weakly dominated by
/// some point of the result (≥ accuracy at ≤ GF/sample) — the
/// property `tests/properties.rs` enforces.
pub fn compile_menu_per_layer(
    model: &Model,
    budget_bits: &[u32],
    act_method: ActQuantMethod,
    calib: Option<&Tensor>,
    val: &Dataset,
    bx_range: std::ops::RangeInclusive<u32>,
    search: PerLayerSearch,
) -> Result<MenuArtifact> {
    let mut cands = uniform_candidates(model, budget_bits, act_method, calib, val, &bx_range)?;
    anyhow::ensure!(
        !cands.is_empty(),
        "no usable operating point for budgets {budget_bits:?} over b̃x {bx_range:?}"
    );
    // base: the best-accuracy uniform candidate (ties -> cheaper);
    // target: the narrowest usable width the sweep produced
    let base = cands
        .iter()
        .max_by(|a, b| {
            a.val_acc
                .total_cmp(&b.val_acc)
                .then(b.gflips_per_sample.total_cmp(&a.gflips_per_sample))
        })
        .cloned()
        .expect("non-empty candidates");
    let lo = cands.iter().map(|c| c.bx_tilde).min().expect("non-empty candidates");
    if lo < base.bx_tilde && search.max_mixed_points > 0 {
        let cfg = QuantConfig::pann(base.bx_tilde, base.r, act_method);
        let base_qm = QuantizedModel::prepare(model, cfg, calib)
            .context("recompile per-layer search base point")?;
        let n_layers = base_qm.plan().layer_certs().len();
        let sens = val.take(search.sensitivity_samples.max(1).min(val.len()));
        let base_sens_acc = eval_quantized(&base_qm, &sens)?.accuracy();
        // energy shares: one metered forward, per-layer Eq.-13 tallies
        let mut meter = base_qm.new_meter();
        let probe = batch_tensor(&sens, 0, sens.len().min(8));
        base_qm.forward(&probe, &mut meter)?;
        let shares: Vec<f64> = meter.layers.iter().map(|l| l.flips).collect();
        let total_share: f64 = shares.iter().sum::<f64>().max(f64::MIN_POSITIVE);
        // sensitivity: accuracy drop of downgrading each layer alone,
        // scored against the energy that downgrade frees (the layer's
        // share scales linearly in b̃x under Eq. 13)
        let mut scored: Vec<(usize, f64)> = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let mut bits = vec![base.bx_tilde; n_layers];
            bits[l] = lo;
            let qm = QuantizedModel::prepare_with_layers(model, cfg, Some(&bits), calib)
                .with_context(|| format!("sensitivity probe for MAC layer {l}"))?;
            let drop = (base_sens_acc - eval_quantized(&qm, &sens)?.accuracy()).max(0.0);
            let saved = (shares[l] / total_share)
                * (1.0 - lo as f64 / base.bx_tilde as f64);
            scored.push((l, drop / saved.max(1e-12)));
        }
        // cheapest accuracy-per-energy first; index breaks ties so the
        // ladder (and the artifact) is deterministic
        scored.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        // greedy ladder: cumulative downgrades, one candidate per step
        let mut bits = vec![base.bx_tilde; n_layers];
        for &(l, _) in scored.iter().take(search.max_mixed_points) {
            bits[l] = lo;
            let qm = QuantizedModel::prepare_with_layers(model, cfg, Some(&bits), calib)
                .with_context(|| format!("compile mixed candidate {bits:?}"))?;
            let res = eval_quantized(&qm, val)?;
            cands.push(Cand {
                bx_tilde: *bits.iter().max().expect("non-empty layer widths"),
                r: base.r,
                gflips_per_sample: res.flips_per_sample / 1e9,
                val_acc: res.accuracy(),
                achieved_adds_per_element: qm.achieved_r(),
                weight_code_bits: qm.weight_code_bits(),
                layer_bits: Some(bits.clone()),
            });
        }
    }
    Ok(finish_menu(model, act_method, cands))
}

/// One menu candidate before pruning: a uniform sweep point, or a
/// mixed-precision point from the per-layer search.
#[derive(Clone)]
struct Cand {
    bx_tilde: u32,
    r: f64,
    gflips_per_sample: f64,
    val_acc: f64,
    achieved_adds_per_element: f64,
    weight_code_bits: u32,
    layer_bits: Option<Vec<u32>>,
}

/// The uniform candidate grid shared by [`compile_menu`] and
/// [`compile_menu_per_layer`]: one equal-power sweep per deduplicated
/// budget width.
fn uniform_candidates(
    model: &Model,
    budget_bits: &[u32],
    act_method: ActQuantMethod,
    calib: Option<&Tensor>,
    val: &Dataset,
    bx_range: &std::ops::RangeInclusive<u32>,
) -> Result<Vec<Cand>> {
    anyhow::ensure!(!budget_bits.is_empty(), "no budget bit widths given");
    // dedup the curve grid *before* sweeping: a repeated bit width
    // would re-run prepare + eval (the two expensive steps) only to
    // produce identical points; distinct widths cannot collide, since
    // a given b̃x maps each power level to a distinct R
    let mut bits: Vec<u32> = budget_bits.to_vec();
    bits.sort_unstable();
    bits.dedup();
    let mut cands: Vec<Cand> = Vec::new();
    for &b in &bits {
        let power = mac_power_unsigned_total(b);
        cands.extend(
            sweep_equal_power(model, power, act_method, calib, val, bx_range.clone())?
                .into_iter()
                .map(|sp| Cand {
                    bx_tilde: sp.bx_tilde,
                    r: sp.r,
                    gflips_per_sample: sp.gflips_per_sample,
                    val_acc: sp.val_acc,
                    achieved_adds_per_element: sp.achieved_adds_per_element,
                    weight_code_bits: sp.weight_code_bits,
                    layer_bits: None,
                }),
        );
    }
    Ok(cands)
}

/// Pareto-prune the candidate union and assemble the artifact. Point
/// names stay stable for uniform points (`ptNN-bxB-rR`); mixed points
/// are labelled by their width vector (`ptNN-mx8.2.8-rR`, summarized
/// for deep models).
fn finish_menu(model: &Model, act_method: ActQuantMethod, cands: Vec<Cand>) -> MenuArtifact {
    let swept = cands.len();
    let kept = pareto_prune(cands, |p| p.gflips_per_sample, |p| p.val_acc);
    let points: Vec<MenuPointSpec> = kept
        .into_iter()
        .enumerate()
        .map(|(i, sp)| MenuPointSpec {
            // index prefix keeps names unique even if two frontier
            // points share (b̃x, rounded R)
            name: match &sp.layer_bits {
                None => format!("pt{i:02}-bx{}-r{:.2}", sp.bx_tilde, sp.r),
                Some(bits) if bits.len() <= 8 => {
                    let label: Vec<String> = bits.iter().map(u32::to_string).collect();
                    format!("pt{i:02}-mx{}-r{:.2}", label.join("."), sp.r)
                }
                Some(bits) => {
                    let narrow = bits.iter().min().expect("non-empty layer widths");
                    format!(
                        "pt{i:02}-mx{}to{}x{}-r{:.2}",
                        sp.bx_tilde,
                        narrow,
                        bits.len(),
                        sp.r
                    )
                }
            },
            bx_tilde: sp.bx_tilde,
            r: sp.r,
            gflips_per_sample: sp.gflips_per_sample,
            val_acc: sp.val_acc,
            quant_method: act_method,
            achieved_adds_per_element: sp.achieved_adds_per_element,
            weight_code_bits: sp.weight_code_bits,
            measured_gflips_per_sample: None,
            layer_bits: sp.layer_bits,
        })
        .collect();
    MenuArtifact {
        model_name: model.name.clone(),
        model_fingerprint: model.fingerprint(),
        macs_per_sample: model.num_macs(),
        swept,
        points,
    }
}

impl MenuArtifact {
    /// Candidates dropped by the Pareto pruning.
    pub fn pruned(&self) -> usize {
        self.swept - self.points.len()
    }

    /// Store serving-side measured costs back into the artifact (the
    /// `pann-menu/v2` calibration loop): each `(point name,
    /// Gflips/sample)` pair updates the matching point's
    /// `measured_gflips_per_sample`. Non-finite or non-positive
    /// measurements and unknown names are skipped — a calibration
    /// pass must never corrupt a menu. Returns how many points were
    /// updated; persist with [`MenuArtifact::save`].
    ///
    /// Sources: [`crate::coordinator::MetricsSnapshot::per_point_measured`]
    /// or the governor ledger
    /// ([`crate::coordinator::GovernorSnapshot::measured_gflips_per_sample`]).
    pub fn apply_calibration<'a>(
        &mut self,
        measured: impl IntoIterator<Item = (&'a str, f64)>,
    ) -> usize {
        let mut updated = 0;
        for (name, gf) in measured {
            if !(gf.is_finite() && gf > 0.0) {
                continue;
            }
            if let Some(p) = self.points.iter_mut().find(|p| p.name == name) {
                p.measured_gflips_per_sample = Some(gf);
                updated += 1;
            }
        }
        updated
    }

    /// One human-readable line per frontier point, cheapest first —
    /// the single listing used by `pann-cli compile-menu`, the e2e
    /// example and the menu bench, so their outputs cannot drift.
    pub fn frontier_lines(&self) -> impl Iterator<Item = String> + '_ {
        self.points.iter().map(|p| {
            format!(
                "{:<18} b̃x={} R={:.2} adds/elem {:.2} {:.6} GF/sample val-acc {:.3}",
                p.name,
                p.bx_tilde,
                p.r,
                p.achieved_adds_per_element,
                p.gflips_per_sample,
                p.val_acc
            )
        })
    }

    /// Serialize to the versioned `menu.json` form.
    pub fn to_json(&self) -> Json {
        let points = self
            .points
            .iter()
            .map(|p| {
                let mut fields = vec![
                    ("name", Json::from(p.name.as_str())),
                    ("bx_tilde", Json::from(p.bx_tilde as usize)),
                    ("r", Json::Num(p.r)),
                    ("gflips_per_sample", Json::Num(p.gflips_per_sample)),
                    ("val_acc", Json::Num(p.val_acc)),
                    ("quant_method", Json::from(p.quant_method.name())),
                    (
                        "achieved_adds_per_element",
                        Json::Num(p.achieved_adds_per_element),
                    ),
                    ("weight_code_bits", Json::from(p.weight_code_bits as usize)),
                ];
                // the v2 additive calibration field, present only once
                // a serving pass wrote it back
                if let Some(m) = p.measured_gflips_per_sample {
                    fields.push(("measured_gflips_per_sample", Json::Num(m)));
                }
                // the v3 additive mixed-precision field, present only
                // on per-layer points
                if let Some(bits) = &p.layer_bits {
                    fields.push((
                        "layer_bits",
                        Json::Arr(bits.iter().map(|&b| Json::from(b as usize)).collect()),
                    ));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::from(MENU_SCHEMA)),
            ("model", Json::from(self.model_name.as_str())),
            // hex string: a u64 does not survive the f64 number path
            (
                "model_fingerprint",
                Json::from(format!("{:016x}", self.model_fingerprint)),
            ),
            ("macs_per_sample", Json::Num(self.macs_per_sample as f64)),
            ("swept", Json::from(self.swept)),
            ("points", Json::Arr(points)),
        ])
    }

    /// Parse the `menu.json` form, rejecting unknown schemas
    /// (`pann-menu/v1`, `v2` and `v3` are all readable; older points
    /// simply carry no measured-cost calibration and no per-layer
    /// widths).
    pub fn from_json(j: &Json) -> Result<MenuArtifact> {
        let schema = j.req("schema")?.as_str().context("schema must be a string")?;
        anyhow::ensure!(
            schema == MENU_SCHEMA || schema == MENU_SCHEMA_V2 || schema == MENU_SCHEMA_V1,
            "unsupported menu schema '{schema}' (this build reads {MENU_SCHEMA_V1}, \
             {MENU_SCHEMA_V2} and {MENU_SCHEMA})"
        );
        let fp_hex = j
            .req("model_fingerprint")?
            .as_str()
            .context("model_fingerprint must be a hex string")?;
        let model_fingerprint =
            u64::from_str_radix(fp_hex, 16).context("parse model_fingerprint")?;
        let mut points = Vec::new();
        let arr = j.req("points")?.as_arr().context("points must be an array")?;
        // every mixed point in one artifact describes the same model,
        // so their layer_bits vectors must agree on the layer count —
        // a hand-edited length mismatch is rejected here, before the
        // definitive per-model arity check at recompile time
        let mut mixed_len: Option<usize> = None;
        for (i, pj) in arr.iter().enumerate() {
            let method_name = pj
                .req("quant_method")?
                .as_str()
                .context("quant_method must be a string")?;
            let quant_method = ActQuantMethod::from_name(method_name)
                .with_context(|| format!("unknown quant_method '{method_name}'"))?;
            let bx_tilde = pj.req("bx_tilde")?.as_usize().context("bx_tilde")? as u32;
            let layer_bits = match pj.get("layer_bits") {
                Some(v) => {
                    anyhow::ensure!(
                        schema == MENU_SCHEMA,
                        "point {i}: layer_bits requires schema {MENU_SCHEMA}, artifact is \
                         tagged '{schema}'"
                    );
                    let arr = v
                        .as_arr()
                        .with_context(|| format!("point {i}: layer_bits must be an array"))?;
                    anyhow::ensure!(!arr.is_empty(), "point {i}: layer_bits is empty");
                    let mut bits = Vec::with_capacity(arr.len());
                    for (k, b) in arr.iter().enumerate() {
                        let b = b
                            .as_usize()
                            .with_context(|| format!("point {i}: layer_bits[{k}]"))?;
                        anyhow::ensure!(
                            (1..=31).contains(&b),
                            "point {i}: layer_bits[{k}] = {b} is outside 1..=31 (the i32 \
                             activation slab)"
                        );
                        bits.push(b as u32);
                    }
                    match mixed_len {
                        None => mixed_len = Some(bits.len()),
                        Some(n) => anyhow::ensure!(
                            bits.len() == n,
                            "point {i}: layer_bits length {} does not match the {} layers \
                             of earlier mixed points",
                            bits.len(),
                            n
                        ),
                    }
                    let widest = *bits.iter().max().expect("non-empty layer_bits");
                    anyhow::ensure!(
                        widest == bx_tilde,
                        "point {i}: bx_tilde {bx_tilde} must equal the widest layer_bits \
                         entry {widest} (the width audit keys off bx_tilde)"
                    );
                    Some(bits)
                }
                None => None,
            };
            points.push(MenuPointSpec {
                name: pj
                    .req("name")?
                    .as_str()
                    .with_context(|| format!("point {i}: name must be a string"))?
                    .to_string(),
                bx_tilde,
                r: pj.req("r")?.as_f64().context("r")?,
                gflips_per_sample: pj
                    .req("gflips_per_sample")?
                    .as_f64()
                    .context("gflips_per_sample")?,
                val_acc: pj.req("val_acc")?.as_f64().context("val_acc")?,
                quant_method,
                achieved_adds_per_element: pj
                    .req("achieved_adds_per_element")?
                    .as_f64()
                    .context("achieved_adds_per_element")?,
                weight_code_bits: pj
                    .req("weight_code_bits")?
                    .as_usize()
                    .context("weight_code_bits")? as u32,
                measured_gflips_per_sample: match pj.get("measured_gflips_per_sample") {
                    Some(v) => {
                        let m = v.as_f64().context("measured_gflips_per_sample")?;
                        // same corruption bar as apply_calibration: a
                        // hand-edited artifact must not smuggle in a
                        // calibration the API refuses to write
                        anyhow::ensure!(
                            m.is_finite() && m > 0.0,
                            "point {i}: measured_gflips_per_sample must be finite and \
                             positive, got {m}"
                        );
                        Some(m)
                    }
                    None => None,
                },
                layer_bits,
            });
        }
        anyhow::ensure!(!points.is_empty(), "menu artifact has no points");
        let swept = j.req("swept")?.as_usize().context("swept")?;
        anyhow::ensure!(
            swept >= points.len(),
            "menu artifact claims {swept} candidates swept but keeps {} points",
            points.len()
        );
        // the serving guarantee ("budget traversal is monotone by
        // construction") rests on this invariant — reject hand-edited
        // or corrupted artifacts that break it instead of silently
        // serving a dominated point
        for w in points.windows(2) {
            anyhow::ensure!(
                w[1].gflips_per_sample > w[0].gflips_per_sample && w[1].val_acc > w[0].val_acc,
                "menu points are not a strictly monotone Pareto frontier ('{}' -> '{}')",
                w[0].name,
                w[1].name
            );
        }
        Ok(MenuArtifact {
            model_name: j.req("model")?.as_str().context("model")?.to_string(),
            model_fingerprint,
            macs_per_sample: j.req("macs_per_sample")?.as_f64().context("macs_per_sample")?
                as u64,
            swept,
            points,
        })
    }

    /// Write `menu.json`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("write {}", path.display()))
    }

    /// Read and parse `menu.json`.
    pub fn load(path: &Path) -> Result<MenuArtifact> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parse {}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("decode {}", path.display()))
    }

    /// Recompile every persisted point into an [`ExecutionPlan`] for
    /// `model`, after verifying the artifact was compiled for exactly
    /// this model (fingerprint match).
    pub fn recompile(
        &self,
        model: &Model,
        calib: Option<&Tensor>,
    ) -> Result<Vec<(MenuPointSpec, Arc<ExecutionPlan>)>> {
        let fp = model.fingerprint();
        anyhow::ensure!(
            fp == self.model_fingerprint,
            "menu was compiled for model '{}' (fingerprint {:016x}), got fingerprint {:016x} — \
             recompile the menu with `pann-cli compile-menu`",
            self.model_name,
            self.model_fingerprint,
            fp
        );
        let mut out = Vec::with_capacity(self.points.len());
        for p in &self.points {
            let cfg = QuantConfig::pann(p.bx_tilde, p.r, p.quant_method);
            // mixed points recompile through the per-layer path; the
            // arity of layer_bits is validated against this model's
            // actual MAC-layer count inside compile_with_layers
            let qm =
                QuantizedModel::prepare_with_layers(model, cfg, p.layer_bits.as_deref(), calib)
                    .with_context(|| format!("recompile menu point '{}'", p.name))?;
            anyhow::ensure!(
                qm.macs_per_sample == self.macs_per_sample,
                "menu point '{}': plan has {} MACs/sample, artifact recorded {}",
                p.name,
                qm.macs_per_sample,
                self.macs_per_sample
            );
            out.push((p.clone(), qm.plan()));
        }
        Ok(out)
    }

    /// Recompile into serving points for a shared (pool) menu.
    pub fn shared_points(
        &self,
        model: &Model,
        calib: Option<&Tensor>,
        max_batch: usize,
    ) -> Result<Vec<SharedPoint>> {
        Ok(self
            .recompile(model, calib)?
            .into_iter()
            .map(|(p, plan)| SharedPoint {
                name: p.name,
                giga_flips_per_sample: p.gflips_per_sample,
                // calibration rides along so the policy can prefer
                // measured-cheaper points among equal modeled costs
                measured_gflips_per_sample: p.measured_gflips_per_sample,
                engine: Arc::new(PlanEngine::new(plan, max_batch)),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn setup() -> (Model, Dataset) {
        let mut model = Model::reference_cnn(17);
        let ds = Dataset::from_synth(synth::digits(48, 18));
        let calib = crate::pann::convert::calib_tensor(&ds, 16);
        model.record_act_stats(&calib).unwrap();
        (model, ds)
    }

    #[test]
    fn pareto_prune_keeps_only_the_frontier() {
        // (cost, acc): b dominates a (same cost, better acc), d
        // dominates e (cheaper, better acc), f extends the frontier.
        let cands = vec![
            ("a", 1.0, 0.50),
            ("b", 1.0, 0.60),
            ("c", 2.0, 0.55), // dominated by b
            ("d", 3.0, 0.80),
            ("e", 4.0, 0.80), // dominated by d (equal acc, pricier)
            ("f", 5.0, 0.90),
        ];
        let kept = pareto_prune(cands, |c| c.1, |c| c.2);
        let names: Vec<&str> = kept.iter().map(|c| c.0).collect();
        assert_eq!(names, vec!["b", "d", "f"]);
        for w in kept.windows(2) {
            assert!(w[1].1 > w[0].1 && w[1].2 > w[0].2);
        }
    }

    #[test]
    fn pareto_prune_single_and_empty() {
        assert!(pareto_prune(Vec::<(f64, f64)>::new(), |c| c.0, |c| c.1).is_empty());
        let one = pareto_prune(vec![(1.0, 0.5)], |c| c.0, |c| c.1);
        assert_eq!(one, vec![(1.0, 0.5)]);
    }

    #[test]
    fn sweep_matches_usable_grid() {
        // Satellite consistency check: the sweep must include exactly
        // the b̃x values `equal_power_r_usable` admits, with its R.
        let (model, ds) = setup();
        let power = mac_power_unsigned_total(2); // P = 10
        let pts =
            sweep_equal_power(&model, power, ActQuantMethod::BnStats, None, &ds, 2..=8).unwrap();
        let want: Vec<(u32, f64)> = (2..=8)
            .filter_map(|bx| equal_power_r_usable(power, bx).map(|r| (bx, r)))
            .collect();
        let got: Vec<(u32, f64)> = pts.iter().map(|p| (p.bx_tilde, p.r)).collect();
        assert_eq!(got, want);
        for p in &pts {
            assert!((p.power_per_element - power).abs() < 1e-9);
            assert!(p.gflips_per_sample > 0.0);
            assert!(p.achieved_adds_per_element >= 0.0);
        }
    }

    #[test]
    fn compiled_menu_is_strictly_monotone() {
        let (model, ds) = setup();
        let menu =
            compile_menu(&model, &[2, 4, 8], ActQuantMethod::BnStats, None, &ds, 2..=8).unwrap();
        assert!(!menu.points.is_empty());
        assert!(menu.swept >= menu.points.len());
        assert_eq!(menu.pruned(), menu.swept - menu.points.len());
        for w in menu.points.windows(2) {
            assert!(
                w[1].gflips_per_sample > w[0].gflips_per_sample,
                "menu costs must strictly increase"
            );
            assert!(w[1].val_acc > w[0].val_acc, "menu accuracy must strictly increase");
        }
        // names unique (pinning relies on it)
        let mut names: Vec<&str> = menu.points.iter().map(|p| p.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), menu.points.len());
    }

    #[test]
    fn duplicate_budgets_do_not_duplicate_points() {
        let (model, ds) = setup();
        let once =
            compile_menu(&model, &[2], ActQuantMethod::BnStats, None, &ds, 2..=6).unwrap();
        let twice =
            compile_menu(&model, &[2, 2], ActQuantMethod::BnStats, None, &ds, 2..=6).unwrap();
        assert_eq!(once.points, twice.points);
        // the duplicate curve is dropped before the sweep, so it is
        // neither evaluated nor miscounted as Pareto-pruned
        assert_eq!(once.swept, twice.swept);
    }

    #[test]
    fn calibration_roundtrips_and_v1_still_loads() {
        let (model, ds) = setup();
        let mut menu =
            compile_menu(&model, &[2], ActQuantMethod::BnStats, None, &ds, 2..=4).unwrap();
        assert!(menu.points.iter().all(|p| p.measured_gflips_per_sample.is_none()));
        // v1- and v2-tagged artifacts (no per-layer fields) still load
        for old in [MENU_SCHEMA_V1, MENU_SCHEMA_V2] {
            let mut j = menu.to_json();
            if let Json::Obj(m) = &mut j {
                m.insert("schema".into(), Json::from(old));
            }
            assert_eq!(MenuArtifact::from_json(&j).unwrap(), menu, "schema {old}");
        }
        // apply a measured cost to the first point; bogus entries are
        // skipped without corrupting the artifact
        let first = menu.points[0].name.clone();
        let n = menu.apply_calibration([
            (first.as_str(), 0.123),
            ("no-such-point", 1.0),
            (first.as_str(), f64::NAN),
            (first.as_str(), -1.0),
        ]);
        assert_eq!(n, 1);
        assert_eq!(menu.points[0].measured_gflips_per_sample, Some(0.123));
        // the calibration survives the v2 JSON round trip
        let back = MenuArtifact::from_json(&menu.to_json()).unwrap();
        assert_eq!(back, menu);
        assert_eq!(back.points[0].measured_gflips_per_sample, Some(0.123));
        assert!(menu.to_json().to_string().contains("pann-menu/v3"));
        // a hand-edited artifact cannot smuggle in a calibration the
        // API refuses to write (same bar as apply_calibration)
        menu.points[0].measured_gflips_per_sample = Some(-1.0);
        let e = MenuArtifact::from_json(&menu.to_json()).unwrap_err();
        assert!(e.to_string().contains("measured_gflips_per_sample"), "{e}");
    }

    #[test]
    fn artifact_json_roundtrip() {
        let (model, ds) = setup();
        let menu =
            compile_menu(&model, &[2, 8], ActQuantMethod::BnStats, None, &ds, 2..=8).unwrap();
        let text = menu.to_json().to_string();
        let back = MenuArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, menu);
    }

    #[test]
    fn loader_rejects_wrong_schema_and_fingerprint() {
        let (model, ds) = setup();
        let menu =
            compile_menu(&model, &[2], ActQuantMethod::BnStats, None, &ds, 2..=4).unwrap();
        // wrong schema
        let mut j = menu.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), Json::from("pann-menu/v999"));
        }
        assert!(MenuArtifact::from_json(&j).is_err());
        // wrong model at recompile time
        let other = Model::reference_cnn(99);
        assert!(menu.recompile(&other, None).is_err());
        // right model recompiles to matching plans
        let pairs = menu.recompile(&model, None).unwrap();
        assert_eq!(pairs.len(), menu.points.len());
    }

    #[test]
    fn loader_rejects_non_monotone_frontier() {
        // the serving guarantee rests on the artifact invariant; a
        // hand-edited menu with a dominated point must not load
        let point = |name: &str, gf: f64, acc: f64| MenuPointSpec {
            name: name.into(),
            bx_tilde: 4,
            r: 2.0,
            gflips_per_sample: gf,
            val_acc: acc,
            quant_method: ActQuantMethod::BnStats,
            achieved_adds_per_element: 2.0,
            weight_code_bits: 3,
            measured_gflips_per_sample: None,
            layer_bits: None,
        };
        let art = MenuArtifact {
            model_name: "m".into(),
            model_fingerprint: 7,
            macs_per_sample: 100,
            swept: 2,
            points: vec![point("a", 1.0, 0.9), point("b", 2.0, 0.8)],
        };
        let e = MenuArtifact::from_json(&art.to_json()).unwrap_err();
        assert!(e.to_string().contains("Pareto"), "{e}");
        // the valid ordering loads
        let ok = MenuArtifact {
            points: vec![point("a", 1.0, 0.8), point("b", 2.0, 0.9)],
            ..art
        };
        assert_eq!(MenuArtifact::from_json(&ok.to_json()).unwrap(), ok);
        // swept must cover the kept points (pruned() would underflow)
        let short = MenuArtifact { swept: 1, ..ok };
        let e = MenuArtifact::from_json(&short.to_json()).unwrap_err();
        assert!(e.to_string().contains("swept"), "{e}");
    }

    #[test]
    fn per_layer_menu_compiles_recompiles_and_dominates_uniform() {
        let (model, ds) = setup();
        let search = PerLayerSearch { sensitivity_samples: 12, max_mixed_points: 2 };
        let menu = compile_menu_per_layer(
            &model,
            &[2, 4],
            ActQuantMethod::BnStats,
            None,
            &ds,
            2..=6,
            search,
        )
        .unwrap();
        // the merged frontier keeps the artifact invariant
        for w in menu.points.windows(2) {
            assert!(w[1].gflips_per_sample > w[0].gflips_per_sample);
            assert!(w[1].val_acc > w[0].val_acc);
        }
        // v3 JSON round trip (layer_bits included when present)
        let back = MenuArtifact::from_json(&menu.to_json()).unwrap();
        assert_eq!(back, menu);
        // every point — uniform and mixed — recompiles, and a mixed
        // point's plan realizes exactly its persisted widths
        let pairs = menu.recompile(&model, None).unwrap();
        assert_eq!(pairs.len(), menu.points.len());
        for (p, plan) in &pairs {
            match &p.layer_bits {
                Some(bits) => {
                    assert_eq!(&plan.layer_widths(), bits);
                    assert_eq!(*bits.iter().max().unwrap(), p.bx_tilde);
                    assert!(p.name.contains("-mx"), "{}", p.name);
                }
                None => assert!(plan.layer_widths().iter().all(|&b| b == p.bx_tilde)),
            }
        }
        // headline claim on a real model: the mixed frontier weakly
        // dominates the uniform frontier (pruning the candidate union
        // can only improve any cost point)
        let uni =
            compile_menu(&model, &[2, 4], ActQuantMethod::BnStats, None, &ds, 2..=6).unwrap();
        for u in &uni.points {
            assert!(
                menu.points.iter().any(|m| m.gflips_per_sample <= u.gflips_per_sample
                    && m.val_acc >= u.val_acc),
                "uniform point {} not weakly dominated by the mixed frontier",
                u.name
            );
        }
    }

    #[test]
    fn loader_validates_layer_bits() {
        let point = |name: &str, gf: f64, acc: f64, bits: Option<Vec<u32>>| MenuPointSpec {
            name: name.into(),
            bx_tilde: bits
                .as_ref()
                .and_then(|b| b.iter().max().copied())
                .unwrap_or(4),
            r: 2.0,
            gflips_per_sample: gf,
            val_acc: acc,
            quant_method: ActQuantMethod::BnStats,
            achieved_adds_per_element: 2.0,
            weight_code_bits: 3,
            measured_gflips_per_sample: None,
            layer_bits: bits,
        };
        let art = |points: Vec<MenuPointSpec>| MenuArtifact {
            model_name: "m".into(),
            model_fingerprint: 7,
            macs_per_sample: 100,
            swept: 4,
            points,
        };
        // a well-formed mixed artifact round-trips with widths intact
        let ok = art(vec![
            point("u", 1.0, 0.8, None),
            point("m1", 2.0, 0.9, Some(vec![2, 4, 4])),
            point("m2", 3.0, 0.95, Some(vec![4, 2, 2])),
        ]);
        let back = MenuArtifact::from_json(&ok.to_json()).unwrap();
        assert_eq!(back, ok);
        assert_eq!(back.points[1].layer_bits.as_deref(), Some(&[2u32, 4, 4][..]));
        // layer_bits is a v3 field: an artifact tagged v1/v2 cannot
        // smuggle one in
        for old in [MENU_SCHEMA_V1, MENU_SCHEMA_V2] {
            let mut j = ok.to_json();
            if let Json::Obj(m) = &mut j {
                m.insert("schema".into(), Json::from(old));
            }
            let e = MenuArtifact::from_json(&j).unwrap_err();
            assert!(e.to_string().contains("requires schema"), "{e}");
        }
        // a width outside the i32 activation slab is rejected, typed
        let e = MenuArtifact::from_json(&art(vec![point("m", 1.0, 0.8, Some(vec![4, 32]))]).to_json())
            .unwrap_err();
        assert!(e.to_string().contains("1..=31"), "{e}");
        // mixed points of one artifact must agree on the layer count
        let e = MenuArtifact::from_json(
            &art(vec![
                point("m1", 1.0, 0.8, Some(vec![2, 4])),
                point("m2", 2.0, 0.9, Some(vec![4, 2, 2])),
            ])
            .to_json(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("does not match"), "{e}");
        // bx_tilde must stay the widest entry (the width audit keys
        // off it)
        let mut p = point("m", 1.0, 0.8, Some(vec![2, 2]));
        p.bx_tilde = 4;
        let e = MenuArtifact::from_json(&art(vec![p]).to_json()).unwrap_err();
        assert!(e.to_string().contains("widest"), "{e}");
        // an empty width vector describes no model
        let e = MenuArtifact::from_json(&art(vec![point("m", 1.0, 0.8, Some(vec![]))]).to_json())
            .unwrap_err();
        assert!(e.to_string().contains("empty"), "{e}");
    }
}
