//! Algorithm 1 of the paper: choosing the optimal `(b̃_x, R)` for a
//! power budget by validating candidate activation bit widths.
//!
//! The candidate evaluation is the shared equal-power sweep core in
//! [`super::menu::sweep_equal_power`] (also behind the Table-15 curve
//! and the menu compiler), so the `R` inversion and its
//! [`crate::power::budget::MIN_R`] cutoff cannot drift between the
//! three call sites.

use crate::data::Dataset;
use crate::nn::{Model, Tensor};
use crate::quant::ActQuantMethod;
use anyhow::Result;

/// A chosen PANN operating point.
#[derive(Clone, Copy, Debug)]
pub struct OperatingPoint {
    /// Activation width `b̃_x`.
    pub bx_tilde: u32,
    /// Requested additions budget (Eq. 13 inversion at the power
    /// budget).
    pub r: f64,
    /// Additions per element the quantizer actually achieved
    /// (`‖w_q‖₁/d`, MAC-weighted) — the realized latency factor,
    /// which undershoots `r` in the small-R regime (Sec. 5.1).
    pub achieved_adds_per_element: f64,
    /// Validation accuracy at this point.
    pub val_acc: f64,
    /// Power per element implied by Eq. (13) with the *requested* R.
    pub power_per_element: f64,
}

/// Algorithm 1: for each candidate `b̃_x`, set `R = P/b̃_x − 0.5`
/// (Eq. 13), quantize, run on the validation set, keep the best.
///
/// Accuracy ties break toward the *lower* `R`: `R` is the latency
/// factor (paper Sec. 6), so among equally accurate points the
/// fastest one wins. (The seed kept the first candidate — the lowest
/// `b̃_x`, i.e. the *highest*-latency point.)
///
/// `power_budget` is in flips per MAC/element (e.g.
/// [`crate::power::model::mac_power_unsigned_total`] of the reference
/// bit width).
pub fn choose_operating_point(
    model: &Model,
    power_budget: f64,
    act_method: ActQuantMethod,
    calib: Option<&Tensor>,
    val: &Dataset,
    bx_range: std::ops::RangeInclusive<u32>,
) -> Result<OperatingPoint> {
    let cands: Vec<OperatingPoint> =
        super::menu::sweep_equal_power(model, power_budget, act_method, calib, val, bx_range)?
            .into_iter()
            .map(|sp| OperatingPoint {
                bx_tilde: sp.bx_tilde,
                r: sp.r,
                achieved_adds_per_element: sp.achieved_adds_per_element,
                val_acc: sp.val_acc,
                power_per_element: sp.power_per_element,
            })
            .collect();
    pick_best(&cands)
        .map(|i| cands[i])
        .ok_or_else(|| anyhow::anyhow!("power budget {power_budget} too small for any bit width"))
}

/// Best candidate by validation accuracy; ties break toward lower `R`
/// (lower latency).
fn pick_best(cands: &[OperatingPoint]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, c) in cands.iter().enumerate() {
        let better = match best {
            None => true,
            Some(b) => {
                c.val_acc > cands[b].val_acc
                    || (c.val_acc == cands[b].val_acc && c.r < cands[b].r)
            }
        };
        if better {
            best = Some(i);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn picks_a_point_within_budget() {
        let mut model = Model::reference_cnn(3);
        let ds = crate::data::Dataset::from_synth(synth::digits(40, 4));
        let calib = crate::pann::convert::calib_tensor(&ds, 16);
        model.record_act_stats(&calib).unwrap();
        let p = crate::power::model::mac_power_unsigned_total(4); // 24 flips
        let op =
            choose_operating_point(&model, p, ActQuantMethod::Aciq, Some(&calib), &ds, 2..=8)
                .unwrap();
        assert!((2..=8).contains(&op.bx_tilde));
        assert!(op.r > 0.0);
        // Eq. 13 consistency: requested point sits on the budget curve.
        assert!((op.power_per_element - p).abs() < 1e-9);
        // achieved R is reported and can only undershoot the request
        // (plus rounding slack, Sec. 5.1).
        assert!(op.achieved_adds_per_element > 0.0);
        assert!(op.achieved_adds_per_element <= op.r + 0.5 + 1e-9);
    }

    #[test]
    fn tiny_budget_errors() {
        let model = Model::reference_cnn(5);
        let ds = crate::data::Dataset::from_synth(synth::digits(8, 6));
        let res = choose_operating_point(&model, 0.5, ActQuantMethod::Dynamic, None, &ds, 2..=8);
        assert!(res.is_err());
    }

    #[test]
    fn larger_budget_never_much_worse() {
        let mut model = Model::reference_cnn(7);
        let ds = crate::data::Dataset::from_synth(synth::digits(48, 8));
        let calib = crate::pann::convert::calib_tensor(&ds, 16);
        model.record_act_stats(&calib).unwrap();
        let lo = choose_operating_point(&model, 10.0, ActQuantMethod::Aciq, Some(&calib), &ds, 2..=8)
            .unwrap();
        let hi = choose_operating_point(&model, 64.0, ActQuantMethod::Aciq, Some(&calib), &ds, 2..=8)
            .unwrap();
        assert!(hi.val_acc + 0.1 >= lo.val_acc, "hi {} lo {}", hi.val_acc, lo.val_acc);
    }

    #[test]
    fn accuracy_ties_break_toward_lower_latency() {
        // Hand-built candidates: b, c, d tie on accuracy; c has the
        // lowest R (lowest latency) and must win. The seed kept the
        // first (highest-R) tied candidate.
        let op = |bx: u32, r: f64, acc: f64| OperatingPoint {
            bx_tilde: bx,
            r,
            achieved_adds_per_element: r,
            val_acc: acc,
            power_per_element: (r + 0.5) * bx as f64,
        };
        let cands = [
            op(2, 4.5, 0.80),
            op(3, 2.83, 0.90),
            op(6, 1.17, 0.90),
            op(4, 2.0, 0.90),
            op(8, 0.75, 0.85),
        ];
        assert_eq!(pick_best(&cands), Some(2), "lowest-R tie must win");
        assert_eq!(pick_best(&[]), None);
        // a strictly better accuracy still beats a faster tie
        let cands = [op(6, 1.17, 0.90), op(2, 4.5, 0.95)];
        assert_eq!(pick_best(&cands), Some(1));
    }
}
