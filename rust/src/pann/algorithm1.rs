//! Algorithm 1 of the paper: choosing the optimal `(b̃_x, R)` for a
//! power budget by validating candidate activation bit widths.

use crate::data::Dataset;
use crate::nn::eval::eval_quantized;
use crate::nn::quantized::{QuantConfig, QuantizedModel};
use crate::nn::{Model, Tensor};
use crate::quant::ActQuantMethod;
use anyhow::Result;

/// A chosen PANN operating point.
#[derive(Clone, Copy, Debug)]
pub struct OperatingPoint {
    pub bx_tilde: u32,
    pub r: f64,
    /// Validation accuracy at this point.
    pub val_acc: f64,
    /// Power per element implied by Eq. (13) with the *requested* R.
    pub power_per_element: f64,
}

/// Algorithm 1: for each candidate `b̃_x`, set `R = P/b̃_x − 0.5`
/// (Eq. 13), quantize, run on the validation set, keep the best.
///
/// `power_budget` is in flips per MAC/element (e.g.
/// [`crate::power::model::mac_power_unsigned_total`] of the reference
/// bit width).
pub fn choose_operating_point(
    model: &Model,
    power_budget: f64,
    act_method: ActQuantMethod,
    calib: Option<&Tensor>,
    val: &Dataset,
    bx_range: std::ops::RangeInclusive<u32>,
) -> Result<OperatingPoint> {
    let mut best: Option<OperatingPoint> = None;
    for bx in bx_range {
        let r = power_budget / bx as f64 - 0.5;
        if r <= 0.05 {
            continue; // budget can't afford this activation width
        }
        let cfg = QuantConfig::pann(bx, r, act_method);
        let qm = QuantizedModel::prepare(model, cfg, calib)?;
        let res = eval_quantized(&qm, val)?;
        let cand = OperatingPoint {
            bx_tilde: bx,
            r,
            val_acc: res.accuracy(),
            power_per_element: crate::power::model::pann_power_per_element(r, bx),
        };
        if best.map_or(true, |b| cand.val_acc > b.val_acc) {
            best = Some(cand);
        }
    }
    best.ok_or_else(|| anyhow::anyhow!("power budget {power_budget} too small for any bit width"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn picks_a_point_within_budget() {
        let mut model = Model::reference_cnn(3);
        let ds = crate::data::Dataset::from_synth(synth::digits(40, 4));
        let calib = crate::pann::convert::calib_tensor(&ds, 16);
        model.record_act_stats(&calib).unwrap();
        let p = crate::power::model::mac_power_unsigned_total(4); // 24 flips
        let op =
            choose_operating_point(&model, p, ActQuantMethod::Aciq, Some(&calib), &ds, 2..=8)
                .unwrap();
        assert!((2..=8).contains(&op.bx_tilde));
        assert!(op.r > 0.0);
        // Eq. 13 consistency: requested point sits on the budget curve.
        assert!((op.power_per_element - p).abs() < 1e-9);
    }

    #[test]
    fn tiny_budget_errors() {
        let model = Model::reference_cnn(5);
        let ds = crate::data::Dataset::from_synth(synth::digits(8, 6));
        let res = choose_operating_point(&model, 0.5, ActQuantMethod::Dynamic, None, &ds, 2..=8);
        assert!(res.is_err());
    }

    #[test]
    fn larger_budget_never_much_worse() {
        let mut model = Model::reference_cnn(7);
        let ds = crate::data::Dataset::from_synth(synth::digits(48, 8));
        let calib = crate::pann::convert::calib_tensor(&ds, 16);
        model.record_act_stats(&calib).unwrap();
        let lo = choose_operating_point(&model, 10.0, ActQuantMethod::Aciq, Some(&calib), &ds, 2..=8)
            .unwrap();
        let hi = choose_operating_point(&model, 64.0, ActQuantMethod::Aciq, Some(&calib), &ds, 2..=8)
            .unwrap();
        assert!(hi.val_acc + 0.1 >= lo.val_acc, "hi {} lo {}", hi.val_acc, lo.val_acc);
    }
}
