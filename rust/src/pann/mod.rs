//! The paper's headline pipeline:
//!
//! 1. **Switch to unsigned arithmetic** (Sec. 4) — exact function-
//!    preserving conversion, large accumulator-power cut.
//! 2. **Remove the multiplier** (Sec. 5) — PANN weight quantization at
//!    an additions budget `R`.
//! 3. **Pick the operating point** (Algorithm 1) — for a power budget
//!    `P`, sweep `b̃_x`, set `R = P/b̃_x − 0.5`, validate, keep the best.
//! 4. **Traverse the trade-off at deployment** (Sec. 6, Tables 14–15)
//!    — latency / memory / accuracy of every point on a budget curve.
//! 5. **Compile the menu** ([`menu`]) — sweep one or more equal-power
//!    curves, Pareto-prune to the accuracy-vs-energy frontier, persist
//!    it as a versioned `menu.json` and recompile it for serving.

pub mod algorithm1;
pub mod convert;
pub mod menu;
pub mod tradeoff;

pub use algorithm1::{choose_operating_point, OperatingPoint};
pub use convert::{pann_at_budget, ptq_baseline, unsigned_of};
pub use menu::{
    compile_menu, compile_menu_per_layer, pareto_prune, sweep_equal_power, MenuArtifact,
    MenuPointSpec, PerLayerSearch,
};
pub use tradeoff::{budget_curve_table, TradeoffRow};
