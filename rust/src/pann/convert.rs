//! Model conversion helpers: baselines, unsigned switch, PANN.

use crate::data::Dataset;
use crate::nn::eval::{batch_tensor, eval_quantized, EvalResult};
use crate::nn::quantized::{Arithmetic, QuantConfig, QuantizedModel};
use crate::nn::{Model, Tensor};
use crate::quant::ActQuantMethod;
use anyhow::Result;

/// Calibration tensor from the first `n` samples of a dataset.
pub fn calib_tensor(ds: &Dataset, n: usize) -> Tensor {
    batch_tensor(ds, 0, n.min(ds.len()))
}

/// Prepare + evaluate a conventional quantized baseline (signed MACs,
/// equal weight/activation bits — the paper's "Base." columns).
pub fn ptq_baseline(
    model: &Model,
    bits: u32,
    method: ActQuantMethod,
    arithmetic: Arithmetic,
    calib: Option<&Tensor>,
    test: &Dataset,
) -> Result<(QuantizedModel, EvalResult)> {
    let mut cfg = QuantConfig::signed_baseline(bits, method);
    cfg.arithmetic = arithmetic;
    if method == ActQuantMethod::Recon {
        cfg.weight_quant = crate::nn::quantized::WeightQuantMethod::RuqRecon;
    }
    let qm = QuantizedModel::prepare(model, cfg, calib)?;
    let res = eval_quantized(&qm, test)?;
    Ok((qm, res))
}

/// The Sec.-4 conversion: same bits, unsigned W⁺/W⁻ arithmetic. The
/// function (and thus accuracy) is identical to the signed baseline;
/// only the power changes.
pub fn unsigned_of(
    model: &Model,
    bits: u32,
    method: ActQuantMethod,
    calib: Option<&Tensor>,
    test: &Dataset,
) -> Result<(QuantizedModel, EvalResult)> {
    ptq_baseline(model, bits, method, Arithmetic::UnsignedMac, calib, test)
}

/// PANN at an explicit `(b̃_x, R)` operating point.
pub fn pann_at_budget(
    model: &Model,
    bx_tilde: u32,
    r: f64,
    method: ActQuantMethod,
    calib: Option<&Tensor>,
    test: &Dataset,
) -> Result<(QuantizedModel, EvalResult)> {
    let cfg = QuantConfig::pann(bx_tilde, r, method);
    let qm = QuantizedModel::prepare(model, cfg, calib)?;
    let res = eval_quantized(&qm, test)?;
    Ok((qm, res))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn setup() -> (Model, Dataset, Tensor) {
        let mut model = Model::reference_cnn(1);
        let ds = Dataset::from_synth(synth::digits(48, 2));
        let calib = calib_tensor(&ds, 16);
        model.record_act_stats(&calib).unwrap();
        (model, ds, calib)
    }

    #[test]
    fn unsigned_preserves_accuracy_cuts_power() {
        let (model, ds, calib) = setup();
        let (_, signed) = ptq_baseline(
            &model,
            4,
            ActQuantMethod::Aciq,
            Arithmetic::SignedMac { acc_bits: 32 },
            Some(&calib),
            &ds,
        )
        .unwrap();
        let (_, unsigned) = unsigned_of(&model, 4, ActQuantMethod::Aciq, Some(&calib), &ds).unwrap();
        assert_eq!(signed.correct, unsigned.correct, "Sec. 4: function preserved");
        // 33% power cut at 4 bits with B = 32 (paper App. A.3.1)
        let save = 1.0 - unsigned.giga_flips / signed.giga_flips;
        assert!((save - 0.333).abs() < 0.01, "save {save}");
    }

    #[test]
    fn pann_cheaper_than_baseline_at_same_bits() {
        let (model, ds, calib) = setup();
        let (_, base) = unsigned_of(&model, 2, ActQuantMethod::Aciq, Some(&calib), &ds).unwrap();
        // PANN tuned to the 2-bit budget: P = 10 flips/MAC, b̃x=6, R≈1.17
        let (_, pann) =
            pann_at_budget(&model, 6, 10.0 / 6.0 - 0.5, ActQuantMethod::Aciq, Some(&calib), &ds)
                .unwrap();
        let ratio = pann.giga_flips / base.giga_flips;
        assert!(ratio < 1.05, "PANN power ratio {ratio}");
        // and at the 2-bit budget PANN must classify better
        assert!(
            pann.accuracy() >= base.accuracy(),
            "pann {} vs base {}",
            pann.accuracy(),
            base.accuracy()
        );
    }
}
