//! The deployment-time trade-off tables (paper Tables 14–15): every
//! `(b̃_x, R)` point on one power-budget curve with its latency,
//! storage and accuracy implications.
//!
//! The per-candidate evaluation is the shared sweep core in
//! [`super::menu::sweep_equal_power`] (one `R` inversion, one
//! [`crate::power::budget::MIN_R`] cutoff for Algorithm 1, this table
//! and the menu compiler alike).

use crate::data::Dataset;
use crate::nn::{Model, Tensor};
use crate::quant::ActQuantMethod;
use anyhow::Result;

/// One row of Table 15 (or, with the Alg.-1 winner only, Table 14).
#[derive(Clone, Copy, Debug)]
pub struct TradeoffRow {
    /// Activation width `b̃_x`.
    pub bx_tilde: u32,
    /// Additions per element = latency factor (paper Sec. 6).
    pub r: f64,
    /// Bits needed to store a weight code (`b_R`).
    pub b_r: u32,
    /// Activation memory factor vs the `b_x`-bit baseline.
    pub act_mem_factor: f64,
    /// Weight memory factor vs the baseline (`b_R / b_x`).
    pub weight_mem_factor: f64,
    /// Test accuracy at this point.
    pub accuracy: f64,
}

/// All operating points on the equal-power curve of a `bx_ref`-bit
/// unsigned MAC (Fig. 3 curve → Table 15 rows).
pub fn budget_curve_table(
    model: &Model,
    bx_ref: u32,
    act_method: ActQuantMethod,
    calib: Option<&Tensor>,
    test: &Dataset,
    bx_range: std::ops::RangeInclusive<u32>,
) -> Result<Vec<TradeoffRow>> {
    let p = crate::power::model::mac_power_unsigned_total(bx_ref);
    let pts = super::menu::sweep_equal_power(model, p, act_method, calib, test, bx_range)?;
    Ok(pts
        .into_iter()
        .map(|sp| TradeoffRow {
            bx_tilde: sp.bx_tilde,
            r: sp.r,
            b_r: sp.weight_code_bits,
            act_mem_factor: sp.bx_tilde as f64 / bx_ref as f64,
            weight_mem_factor: sp.weight_code_bits as f64 / bx_ref as f64,
            accuracy: sp.val_acc,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn curve_rows_consistent() {
        let mut model = Model::reference_cnn(9);
        let ds = crate::data::Dataset::from_synth(synth::digits(32, 10));
        let calib = crate::pann::convert::calib_tensor(&ds, 16);
        model.record_act_stats(&calib).unwrap();
        let rows = budget_curve_table(&model, 2, ActQuantMethod::Aciq, Some(&calib), &ds, 2..=8)
            .unwrap();
        assert!(rows.len() >= 5);
        // R decreases as b̃x grows along one curve (Table 15 latency col)
        for w in rows.windows(2) {
            assert!(w[1].r < w[0].r);
        }
        // Table 15: on the 2-bit curve, b̃x=6 has R ≈ 1.16, b̃x=8 R = 0.75
        let r6 = rows.iter().find(|r| r.bx_tilde == 6).unwrap();
        assert!((r6.r - (10.0 / 6.0 - 0.5)).abs() < 1e-9);
        let r8 = rows.iter().find(|r| r.bx_tilde == 8).unwrap();
        assert!((r8.r - 0.75).abs() < 1e-9);
        // memory factors follow their definitions
        assert!((r6.act_mem_factor - 3.0).abs() < 1e-9);
        assert!(r6.b_r >= 1);
    }
}
