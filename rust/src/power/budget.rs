//! Power budgets and equal-power curves (paper Fig. 3, Sec. 5.2) and
//! network-level Giga-bit-flip accounting (Tables 2, 7–9).

use super::model::{mac_power_unsigned_total, pann_power_per_element};

/// One equal-power curve of Fig. 3: the set of `(b̃_x, R)` pairs whose
/// PANN power equals that of a `b_x`-bit unsigned MAC.
#[derive(Clone, Debug)]
pub struct EqualPowerCurve {
    /// The reference MAC bit width whose power defines the curve.
    pub bx_ref: u32,
    /// The power level (flips per MAC / per element).
    pub power: f64,
    /// `(b̃_x, R)` samples along the curve for b̃_x = 1..=16.
    pub points: Vec<(u32, f64)>,
}

/// Smallest additions budget `R` an operating point is allowed to run
/// at. Below this the PANN quantizer rounds essentially every weight
/// to code 0 (Sec. 5.1's "as close as possible" undershoot regime) and
/// the point is useless in practice. This is the single cutoff shared
/// by Algorithm 1, the Table-15 curve sweep and the menu compiler —
/// the seed carried two private, mutually inconsistent copies of it
/// (`r <= 0.05` in `pann/algorithm1.rs` and `pann/tradeoff.rs` vs
/// `r >= 0.0` here).
pub const MIN_R: f64 = 0.05;

/// Number of additions `R` that puts PANN at power `p` with activation
/// width `b̃_x` (inverting Eq. (13)); `None` if even `R = 0` overshoots.
pub fn equal_power_r(p: f64, bx_tilde: u32) -> Option<f64> {
    let r = p / bx_tilde as f64 - 0.5;
    (r >= 0.0).then_some(r)
}

/// [`equal_power_r`] restricted to *usable* operating points: `None`
/// when the inverted `R` falls below [`MIN_R`]. Every sweep over a
/// budget curve (Algorithm 1, Table 15, the menu compiler) goes
/// through this so the cutoff cannot drift between call sites.
pub fn equal_power_r_usable(p: f64, bx_tilde: u32) -> Option<f64> {
    equal_power_r(p, bx_tilde).filter(|&r| r >= MIN_R)
}

impl EqualPowerCurve {
    /// Build the curve matching a `b_x`-bit unsigned MAC.
    pub fn for_unsigned_mac(bx_ref: u32) -> Self {
        let power = mac_power_unsigned_total(bx_ref);
        let points = (1..=16)
            .filter_map(|bt| equal_power_r(power, bt).map(|r| (bt, r)))
            .collect();
        EqualPowerCurve { bx_ref, power, points }
    }

    /// `R` on this curve at a given activation width.
    pub fn r_at(&self, bx_tilde: u32) -> Option<f64> {
        equal_power_r(self.power, bx_tilde)
    }
}

/// Network-level power in Giga bit flips: per-MAC (or per-element)
/// power times the number of MACs (paper Table 2 caption).
pub fn network_power_giga(per_mac_flips: f64, num_macs: u64) -> f64 {
    per_mac_flips * num_macs as f64 / 1e9
}

/// PANN network power in Giga bit flips at `R` additions/element.
pub fn pann_network_power_giga(r: f64, bx_tilde: u32, num_macs: u64) -> f64 {
    network_power_giga(pann_power_per_element(r, bx_tilde), num_macs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_power_levels_match_fig3() {
        // P_MAC^u = 0.5 bx^2 + 4 bx
        assert_eq!(EqualPowerCurve::for_unsigned_mac(2).power, 10.0);
        assert_eq!(EqualPowerCurve::for_unsigned_mac(4).power, 24.0);
        assert_eq!(EqualPowerCurve::for_unsigned_mac(8).power, 64.0);
    }

    #[test]
    fn r_tradeoff_monotone() {
        // Along one curve, increasing b̃_x must decrease R.
        let c = EqualPowerCurve::for_unsigned_mac(4);
        for w in c.points.windows(2) {
            assert!(w[1].1 < w[0].1, "{:?}", c.points);
        }
    }

    #[test]
    fn equal_power_consistency() {
        // Any point on the curve reproduces the curve's power by Eq 13.
        let c = EqualPowerCurve::for_unsigned_mac(6);
        for &(bt, r) in &c.points {
            let p = pann_power_per_element(r, bt);
            assert!((p - c.power).abs() < 1e-9);
        }
    }

    #[test]
    fn table15_latency_row() {
        // Table 15: on the 2-bit curve (P=10), b̃_x = 6 gives R ≈ 1.16,
        // b̃_x = 8 gives R = 0.75, b̃_x = 2 gives R = 4.5.
        assert!((equal_power_r(10.0, 6).unwrap() - 1.1667).abs() < 1e-3);
        assert!((equal_power_r(10.0, 8).unwrap() - 0.75).abs() < 1e-9);
        assert!((equal_power_r(10.0, 2).unwrap() - 4.5).abs() < 1e-9);
    }

    #[test]
    fn usable_r_cutoff_consistent() {
        // One documented cutoff: usable iff the inverted R >= MIN_R.
        // On the 2-bit curve (P = 10), b̃_x = 16 gives R = 0.125 ≥ MIN_R
        // (usable) while b̃_x = 20 gives R = 0.0 (on the curve but not
        // usable) and b̃_x = 32 overshoots even at R = 0.
        assert_eq!(equal_power_r_usable(10.0, 16), Some(0.125));
        assert_eq!(equal_power_r(10.0, 20), Some(0.0));
        assert_eq!(equal_power_r_usable(10.0, 20), None);
        assert_eq!(equal_power_r(10.0, 32), None);
        assert_eq!(equal_power_r_usable(10.0, 32), None);
        // The boundary itself is usable (the seed's `r <= 0.05`
        // excluded it); tolerance because 0.55 is not a dyadic f64.
        let p = (MIN_R + 0.5) * 8.0;
        let r = equal_power_r_usable(p, 8).expect("boundary point must be usable");
        assert!((r - MIN_R).abs() < 1e-12, "{r}");
    }

    #[test]
    fn giga_accounting_resnet50_row() {
        // Table 2: 8-bit row is 265 Gflips for ResNet-50's 4.14e9 MACs
        // at P_MAC^u(8) = 64 -> 4.14e9*64/1e9 ≈ 265.
        let p = network_power_giga(64.0, 4_140_000_000);
        assert!((p - 264.96).abs() < 0.5, "{p}");
    }
}
