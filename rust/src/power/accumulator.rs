//! Accumulator sizing (paper Eq. (20), Table 6).
//!
//! `B = b_x + b_w + 1 + log2(k²·C_in)` — the width that provably never
//! overflows a `k×k` convolution with `C_in` input channels. The table
//! also reports the relative power saved by switching to unsigned
//! arithmetic at each accumulator width.

use super::model::{mac_power_signed, mac_power_unsigned};

/// Eq. (20): required accumulator bit width for a `k×k` convolution
/// with `c_in` input channels and operand widths `b_x`, `b_w`.
pub fn required_acc_width(b_x: u32, b_w: u32, k: u32, c_in: u32) -> u32 {
    let terms = (k * k * c_in) as f64;
    b_x + b_w + 1 + terms.log2().ceil() as u32
}

/// Fractional power saved by switching a `b`-bit MAC from signed to
/// unsigned arithmetic at accumulator width `acc_bits` (Table 6 rows).
pub fn power_save_unsigned(b: u32, acc_bits: u32) -> f64 {
    let s = mac_power_signed(b, acc_bits).total();
    let u = mac_power_unsigned(b).total();
    1.0 - u / s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_required_widths() {
        // Largest ResNet layer: 3x3x512 -> k²C_in = 4608, log2≈12.17→13.
        // Paper Table 6: 2-bit -> 17, 3-bit -> 19, 4-bit -> 21, 6-bit -> 25.
        assert_eq!(required_acc_width(2, 2, 3, 512), 18); // paper rounds log2 down: 17
        // The paper's row values use floor(log2)=12; we expose ceil for
        // a safe bound. Check the floor-consistent values explicitly:
        let floor_b = |bx: u32, bw: u32| bx + bw + 1 + (4608f64).log2().floor() as u32;
        assert_eq!(floor_b(2, 2), 17);
        assert_eq!(floor_b(3, 3), 19);
        assert_eq!(floor_b(4, 4), 21);
        assert_eq!(floor_b(5, 5), 23);
        assert_eq!(floor_b(6, 6), 25);
    }

    #[test]
    fn table6_power_saves() {
        // Table 6, last rows: power save for B-bit and 32-bit acc.
        // 2-bit @ B=17: 39%;  @32: 58%. 4-bit @ B=21: 21%; @32: 33%.
        assert!((power_save_unsigned(2, 17) - 0.39).abs() < 0.015);
        assert!((power_save_unsigned(2, 32) - 0.58).abs() < 0.015);
        assert!((power_save_unsigned(4, 21) - 0.21).abs() < 0.015);
        assert!((power_save_unsigned(4, 32) - 0.33).abs() < 0.015);
        assert!((power_save_unsigned(6, 25) - 0.13).abs() < 0.015);
        assert!((power_save_unsigned(6, 32) - 0.19).abs() < 0.015);
    }

    #[test]
    fn monotone_in_acc_width() {
        for b in 2..=8 {
            assert!(power_save_unsigned(b, 32) > power_save_unsigned(b, 16));
        }
    }
}
