//! Analytic power models of the paper (Secs. 3–5), in units of average
//! bit flips per instruction.
//!
//! These are the closed forms the paper derives from its toggle
//! simulations and then uses for *all* of its network-level accounting
//! (Tables 2, 7–9 report `(P_mult^u + P_acc^u) × #MACs`). The sibling
//! [`crate::bitflip`] simulators validate the shapes; this module is
//! what every downstream experiment consumes.

pub mod accumulator;
pub mod budget;
pub mod model;

pub use accumulator::{power_save_unsigned, required_acc_width};
pub use budget::{equal_power_r, network_power_giga, EqualPowerCurve};
pub use model::{
    mac_power_signed, mac_power_unsigned, mult_power_mixed_signed, pann_power_per_element,
    PowerBreakdown,
};
