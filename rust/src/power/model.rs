//! The paper's per-instruction power formulas.
//!
//! | Eq. | Quantity | Formula |
//! |-----|----------|---------|
//! | (1) | signed multiplier      | `P_mult = 0.5b² + b` |
//! | (2) | signed accumulator     | `P_acc = 0.5B + 2b` |
//! | (3) | unsigned multiplier    | `P_mult^u = 0.5b² + b` |
//! | (4) | unsigned accumulator   | `P_acc^u = 3b` |
//! | (7) | mixed-width multiplier | `0.5·max(b_w,b_x)² + 0.5(b_w+b_x)` |
//! | (13)| PANN per element       | `(R + 0.5)·b̃_x` |

/// Per-MAC power split into multiplier and accumulator parts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PowerBreakdown {
    /// Multiplier flips per instruction (0 for PANN).
    pub mult: f64,
    /// Accumulator flips per instruction.
    pub acc: f64,
}

impl PowerBreakdown {
    /// Total flips per instruction (multiplier + accumulator).
    pub fn total(&self) -> f64 {
        self.mult + self.acc
    }

    /// Both components scaled by a device-class energy factor.
    ///
    /// The per-instruction formulas above count *logical* bit flips;
    /// what a flip costs in joules depends on the silicon it runs on
    /// (process node, cell library). Device profiles
    /// ([`crate::scenario::DeviceProfile`]) express that as one scalar
    /// multiplier applied uniformly to both halves of the breakdown,
    /// keeping the mult/acc ratio — which is what the paper's
    /// equations predict — device-independent.
    pub fn scaled(&self, factor: f64) -> PowerBreakdown {
        PowerBreakdown { mult: self.mult * factor, acc: self.acc * factor }
    }
}

/// Eq. (1)+(2): signed `b×b` MAC with a `B`-bit accumulator.
pub fn mac_power_signed(b: u32, acc_bits: u32) -> PowerBreakdown {
    let b = b as f64;
    let bb = acc_bits as f64;
    PowerBreakdown {
        mult: 0.5 * b * b + b,
        acc: 0.5 * bb + 2.0 * b,
    }
}

/// Eq. (3)+(4): unsigned `b×b` MAC. The accumulator input only sees
/// the live `b_acc = 2b` product bits, so `P_acc^u = 3b` independent of
/// the physical accumulator width.
pub fn mac_power_unsigned(b: u32) -> PowerBreakdown {
    let b = b as f64;
    PowerBreakdown {
        mult: 0.5 * b * b + b,
        acc: 3.0 * b,
    }
}

/// Eq. (7): signed multiplier with different operand widths. The
/// internal activity is governed by the larger width (Observation 2).
pub fn mult_power_mixed_signed(b_w: u32, b_x: u32) -> f64 {
    let m = b_w.max(b_x) as f64;
    0.5 * m * m + 0.5 * (b_w + b_x) as f64
}

/// Eq. (13): PANN power per input element at `R` additions per element
/// and activation width `b̃_x`: `(R + 0.5)·b̃_x` — `R·b̃_x` for the
/// burst's sum+FF toggling and `0.5·b̃_x` for the single input-bus load.
pub fn pann_power_per_element(r: f64, bx_tilde: u32) -> f64 {
    assert!(r >= 0.0);
    (r + 0.5) * bx_tilde as f64
}

/// Unsigned MAC total used for the equal-power curves of Fig. 3:
/// `P_MAC^u = 0.5·b_x² + 4·b_x` (Eqs. (3)+(4) with b = b_x).
pub fn mac_power_unsigned_total(b_x: u32) -> f64 {
    mac_power_unsigned(b_x).total()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signed_example_from_paper() {
        // Sec. 3: b=4, B=32 -> P_mult + P_acc = 36, of which the
        // accumulator input (0.5B = 16) is 44.4%.
        let p = mac_power_signed(4, 32);
        assert_eq!(p.total(), 36.0);
        assert!((16.0 / p.total() - 0.444).abs() < 1e-3);
    }

    #[test]
    fn unsigned_cuts_accumulator() {
        // App. A.3.1: at b=4, B=32 the unsigned MAC is ~33% cheaper.
        let s = mac_power_signed(4, 32).total();
        let u = mac_power_unsigned(4).total();
        assert!((1.0 - u / s - 0.333).abs() < 0.01, "save {}", 1.0 - u / s);
    }

    #[test]
    fn fig1_claim_58_percent_at_2bit() {
        // Fig. 1 / Fig. 15: 2-bit networks, 32-bit accumulator ->
        // switching to unsigned cuts 58%.
        let s = mac_power_signed(2, 32).total();
        let u = mac_power_unsigned(2).total();
        let save = 1.0 - u / s;
        assert!((save - 0.58).abs() < 0.01, "save {save}");
    }

    #[test]
    fn mixed_width_max_dominates() {
        assert_eq!(mult_power_mixed_signed(2, 8), 0.5 * 64.0 + 5.0);
        assert_eq!(mult_power_mixed_signed(8, 8), 0.5 * 64.0 + 8.0);
        // shrinking only b_w from 8 to 2 saves just 3 of 40 flips
        let full = mult_power_mixed_signed(8, 8);
        let small = mult_power_mixed_signed(2, 8);
        assert!(small / full > 0.9);
    }

    #[test]
    fn pann_eq13() {
        assert_eq!(pann_power_per_element(2.0, 4), 10.0);
        assert_eq!(pann_power_per_element(0.5, 8), 8.0);
    }

    #[test]
    fn scaled_preserves_mult_acc_ratio() {
        let p = mac_power_signed(4, 32);
        let s = p.scaled(1.25);
        assert!((s.total() - p.total() * 1.25).abs() < 1e-12);
        assert!((s.mult / s.acc - p.mult / p.acc).abs() < 1e-12);
    }

    #[test]
    fn unsigned_total_curve() {
        assert_eq!(mac_power_unsigned_total(2), 10.0);
        assert_eq!(mac_power_unsigned_total(4), 24.0);
        assert_eq!(mac_power_unsigned_total(8), 64.0);
    }
}
