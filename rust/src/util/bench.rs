//! Micro-benchmark harness (criterion is not available in the offline
//! registry of this build, so the `cargo bench` targets use this).
//!
//! Measures wall-clock per iteration with warm-up, reports mean ± std
//! and throughput, and prevents the optimizer from deleting work via
//! `std::hint::black_box`.

use crate::util::Json;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case label.
    pub name: String,
    /// Timed iterations.
    pub iters: u64,
    /// Mean wall time per iteration, nanoseconds.
    pub mean_ns: f64,
    /// Standard deviation of the per-iteration time, nanoseconds.
    pub std_ns: f64,
    /// Fastest iteration, nanoseconds.
    pub min_ns: f64,
}

impl BenchResult {
    /// Human line, e.g. `conv_hot   123.4 µs/iter (±2.1) [64 iters]`.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter (±{}) [{} iters]",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            self.iters
        )
    }

    /// items/sec given items-per-iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }

    /// Machine-readable form for the `BENCH_*.json` perf-trajectory
    /// files.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("std_ns", Json::Num(self.std_ns)),
            ("min_ns", Json::Num(self.min_ns)),
        ])
    }
}

/// Write a JSON report next to the bench (e.g. `BENCH_engine.json`) so
/// later PRs can track the perf trajectory without parsing stdout.
pub fn write_json(path: &str, v: &Json) -> std::io::Result<()> {
    std::fs::write(path, format!("{v}\n"))
}

/// Build a provenance-stamped artifact document: every versioned JSON
/// file this crate commits (`BENCH_*.json`, traces, scenario reports)
/// carries a `schema` tag and a human `provenance` string alongside
/// its payload fields, so a reader can tell what produced the file and
/// whether absolute numbers are comparable across machines. Keep the
/// provenance text free of timestamps when the producer promises
/// byte-identical output for identical inputs.
pub fn stamped(schema: &str, provenance: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("schema", Json::from(schema)), ("provenance", Json::from(provenance))];
    pairs.extend(fields);
    Json::obj(pairs)
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.1} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-scaling iteration count to fill ~`budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warm-up and calibration: find an iteration count that takes ≥1ms.
    let mut calib_iters = 1u64;
    let per_iter_ns = loop {
        let t0 = Instant::now();
        for _ in 0..calib_iters {
            f();
        }
        let dt = t0.elapsed();
        if dt >= Duration::from_millis(1) || calib_iters >= 1 << 24 {
            break dt.as_nanos() as f64 / calib_iters as f64;
        }
        calib_iters *= 4;
    };
    // Sample batches until the budget is spent (at least 5 samples).
    let batch = ((1e6 / per_iter_ns).ceil() as u64).max(1);
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 5 {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
        if samples.len() > 10_000 {
            break;
        }
    }
    let mean = crate::util::stats::mean(&samples);
    let std = crate::util::stats::std_dev(&samples);
    let (min, _) = crate::util::stats::min_max(&samples);
    BenchResult {
        name: name.to_string(),
        iters: batch * samples.len() as u64,
        mean_ns: mean,
        std_ns: std,
        min_ns: min,
    }
}

/// Run and print a benchmark with the default 0.5s budget.
pub fn run<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench(name, Duration::from_millis(500), f);
    println!("{}", r.line());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stamped_puts_schema_and_provenance_first_class() {
        let doc = stamped("x/v1", "hand-rolled", vec![("n", Json::from(3usize))]);
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some("x/v1"));
        assert_eq!(doc.get("provenance").and_then(Json::as_str), Some("hand-rolled"));
        assert_eq!(doc.get("n").and_then(Json::as_usize), Some(3));
    }

    #[test]
    fn measures_something() {
        let mut acc = 0u64;
        let r = bench("noop-ish", Duration::from_millis(20), || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
    }
}
