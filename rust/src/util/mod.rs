//! Small self-contained utilities: deterministic RNG, a minimal JSON
//! reader/writer (the crate registry available to this build has no
//! `serde`/`rand`), descriptive statistics and a micro-bench harness.

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
