//! Minimal JSON parser / serializer.
//!
//! Used for model manifests, experiment reports and the coordinator's
//! operating-point tables. Supports the full JSON grammar that our own
//! tooling emits (objects, arrays, strings with escapes, f64 numbers,
//! bools, null). Not a general-purpose validator — but it round-trips
//! everything `python/compile/*` writes.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys, so output is deterministic).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with context.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    /// The number value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number value truncated to usize, if this is a `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn nums<I: IntoIterator<Item = f64>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(Json::Num).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"[[[{"x":{"y":[0]}}]]]"#).unwrap();
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("3.25").unwrap().as_f64(), Some(3.25));
        assert_eq!(Json::parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""A""#).unwrap();
        assert_eq!(v.as_str(), Some("A"));
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 4, "s": "x", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(4));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("zz").is_none());
        assert!(v.req("zz").is_err());
    }
}
