//! Descriptive statistics used by the experiment harness and benches.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Min and max of a slice (NaN-free input assumed).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| (lo.min(x), hi.max(x)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118).abs() < 1e-3);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 99.0) - 99.0).abs() < 1e-9);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
