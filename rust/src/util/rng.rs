//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via SplitMix64 — the standard construction. All
//! experiments in the repo are seeded so every table/figure regenerates
//! bit-identically.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[lo, hi)` (half-open; requires `lo < hi`).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean / std-dev.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A fresh generator split off this one (for parallel streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.range_i64(-8, 8);
            assert!((-8..8).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
