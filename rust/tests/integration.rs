//! Cross-layer integration tests.
//!
//! Tests that need `make artifacts` outputs skip gracefully when the
//! artifacts are absent, so `cargo test` is green on a fresh clone.

use pann::data::Dataset;
use pann::experiments::Ctx;
use pann::nn::eval::{batch_tensor, eval_fp32, eval_quantized};
use pann::nn::quantized::{QuantConfig, QuantizedModel};
use pann::nn::Model;
use pann::quant::ActQuantMethod;
use std::path::Path;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("models").join("cnn-s").join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("[skip] artifacts not built");
        None
    }
}

#[test]
fn trained_manifest_loads_and_classifies() {
    let Some(root) = artifacts() else { return };
    let model = Model::load(&root.join("models/cnn-s")).unwrap();
    let ds = Dataset::load(&root.join("data/digits"), "test").unwrap();
    let res = eval_fp32(&model, &ds.take(256)).unwrap();
    assert!(
        res.accuracy() > 0.8,
        "trained cnn-s should classify digits well, got {}",
        res.accuracy()
    );
}

#[test]
fn ptq_pipeline_on_trained_model() {
    let Some(root) = artifacts() else { return };
    let model = Model::load(&root.join("models/cnn-s")).unwrap();
    let ds = Dataset::load(&root.join("data/digits"), "test").unwrap().take(192);
    let calib_ds = Dataset::load(&root.join("data/digits"), "calib").unwrap();
    let calib = batch_tensor(&calib_ds, 0, calib_ds.len());

    // 8-bit unsigned baseline ≈ fp32; 2-bit collapses; PANN at the
    // 2-bit budget recovers (the paper's Table 7 story).
    let fp = eval_fp32(&model, &ds).unwrap();
    let q8 = QuantizedModel::prepare(&model, QuantConfig::unsigned_baseline(8, ActQuantMethod::Aciq), Some(&calib)).unwrap();
    let r8 = eval_quantized(&q8, &ds).unwrap();
    assert!(r8.accuracy() > fp.accuracy() - 0.05, "8-bit {} vs fp {}", r8.accuracy(), fp.accuracy());

    let q2 = QuantizedModel::prepare(&model, QuantConfig::unsigned_baseline(2, ActQuantMethod::Aciq), Some(&calib)).unwrap();
    let r2 = eval_quantized(&q2, &ds).unwrap();

    let pann = QuantizedModel::prepare(
        &model,
        QuantConfig::pann(6, 10.0 / 6.0 - 0.5, ActQuantMethod::Aciq),
        Some(&calib),
    )
    .unwrap();
    let rp = eval_quantized(&pann, &ds).unwrap();
    assert!(
        rp.accuracy() >= r2.accuracy(),
        "PANN {} should beat the 2-bit baseline {}",
        rp.accuracy(),
        r2.accuracy()
    );
    // equal power by construction (both at the 2-bit unsigned budget)
    let ratio = rp.giga_flips / r2.giga_flips;
    assert!(ratio < 1.1, "power ratio {ratio}");
}

#[test]
fn pjrt_fp32_matches_native_engine() {
    let Some(root) = artifacts() else { return };
    let hlo = root.join("hlo");
    if !hlo.join("cnn-s_fp32.hlo.txt").exists() {
        eprintln!("[skip] hlo artifacts not built");
        return;
    }
    use pann::runtime::{ArtifactManifest, CpuRuntime};
    let manifest = ArtifactManifest::load(&hlo).unwrap();
    let spec = manifest
        .executables
        .iter()
        .find(|e| e.model == "cnn-s" && e.variant == "fp32")
        .unwrap();
    let rt = CpuRuntime::new().unwrap();
    let lm = rt.load(&spec.file, &spec.input_shape).unwrap();

    let model = Model::load(&root.join("models/cnn-s")).unwrap();
    let ds = Dataset::load(&root.join("data/digits"), "test").unwrap();
    let x = batch_tensor(&ds, 0, spec.batch);
    let got = lm.run(&x.data).unwrap();
    let want = model.forward(&x).unwrap();
    assert_eq!(got.len(), want.data.len());
    for (a, b) in got.iter().zip(&want.data) {
        assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn pjrt_pann_artifact_classifies() {
    let Some(root) = artifacts() else { return };
    let hlo = root.join("hlo");
    if !hlo.join("manifest.json").exists() {
        eprintln!("[skip] hlo artifacts not built");
        return;
    }
    use pann::runtime::{ArtifactManifest, CpuRuntime};
    let manifest = ArtifactManifest::load(&hlo).unwrap();
    let rt = CpuRuntime::new().unwrap();
    let ds = Dataset::load(&root.join("data/digits"), "test").unwrap();
    for variant in ["pann-p8", "pann-p2"] {
        let spec = manifest
            .executables
            .iter()
            .find(|e| e.model == "cnn-s" && e.variant == variant)
            .unwrap();
        let lm = rt.load(&spec.file, &spec.input_shape).unwrap();
        let mut correct = 0;
        let n = 64;
        for start in (0..n).step_by(spec.batch) {
            let x = batch_tensor(&ds, start, spec.batch);
            let out = lm.run(&x.data).unwrap();
            let classes = out.len() / spec.batch;
            for i in 0..spec.batch {
                let row = &out[i * classes..(i + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == ds.y[start + i] as usize {
                    correct += 1;
                }
            }
        }
        let acc = correct as f64 / n as f64;
        assert!(acc > 0.5, "{variant}: accuracy {acc} too low");
    }
}

#[test]
fn python_rust_pann_quantizers_agree() {
    // The achieved additions budget recorded by aot.py must match the
    // rust PannQuant on the same weights.
    let Some(root) = artifacts() else { return };
    let model = Model::load(&root.join("models/cnn-s")).unwrap();
    let mut all_w = Vec::new();
    for node in &model.nodes {
        if let pann::nn::layers::Op::Conv { w, .. } | pann::nn::layers::Op::Linear { w, .. } =
            &node.op
        {
            all_w.push(w.data.clone());
        }
    }
    assert!(!all_w.is_empty());
    for r in [1.0, 2.5, 7.5] {
        for w in &all_w {
            let pw = pann::quant::pann::PannQuant::new(r).quantize(w);
            assert!(
                (pw.adds_per_element - r).abs() / r < 0.15,
                "R={r} achieved {}",
                pw.adds_per_element
            );
        }
    }
}

#[test]
fn end_to_end_native_serving() {
    // Serve the reference model through the coordinator without PJRT.
    use pann::coordinator::server::NativeEngine;
    use pann::coordinator::{EnginePoint, Server, ServerConfig};
    let mut model = Model::reference_cnn(5);
    let ds = Dataset::from_synth(pann::data::synth::digits(96, 6));
    let stats = batch_tensor(&ds, 0, 48);
    model.record_act_stats(&stats).unwrap();
    let srv = Server::start(
        move || {
            let mut points = Vec::new();
            for (bits, bx, r) in [(2u32, 6u32, 10.0 / 6.0 - 0.5), (8, 8, 7.5)] {
                let qm = QuantizedModel::prepare(
                    &model,
                    QuantConfig::pann(bx, r, ActQuantMethod::BnStats),
                    None,
                )?;
                points.push(EnginePoint {
                    name: format!("p{bits}"),
                    giga_flips_per_sample: pann::power::model::mac_power_unsigned_total(bits)
                        * model.num_macs() as f64
                        / 1e9,
                    engine: Box::new(NativeEngine { qm, sample_shape: vec![1, 16, 16] }),
                });
            }
            Ok(points)
        },
        256,
        ServerConfig::default(),
    )
    .unwrap();
    let h = srv.handle();
    // unlimited budget -> p8; tight -> p2
    let r = h.infer(ds.sample(0).to_vec()).unwrap();
    assert_eq!(r.point, "p8");
    h.set_budget(0.001);
    let r = h.infer(ds.sample(1).to_vec()).unwrap();
    assert_eq!(r.point, "p2");
    let m = h.metrics();
    assert_eq!(m.requests, 2);
    assert!(m.total_giga_flips > 0.0);
    srv.shutdown();
}

#[test]
fn experiment_registry_complete() {
    // every experiment id in DESIGN.md's index exists
    let ids = pann::experiments::ids();
    for want in [
        "table1", "table2", "table4", "table5", "table6", "table7", "table8", "table9",
        "table10", "table11", "table12", "table13", "table14", "table15", "fig1", "fig3",
        "fig4", "fig5", "fig6", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14",
        "fig16",
    ] {
        assert!(ids.contains(&want), "missing experiment {want}");
    }
}

#[test]
fn qat_results_present_and_ordered() {
    let Some(root) = artifacts() else { return };
    let ctx = Ctx { artifacts: root.to_path_buf(), quick: true };
    let Some(results) = ctx.qat_results() else {
        eprintln!("[skip] qat_results.json missing");
        return;
    };
    let acc = |k: &str| results.get(k).and_then(|v| v.get("acc")).and_then(|v| v.as_f64());
    // Table 4 ordering at 4/4 on cnn-s: PANN(2x) > AdderNet(2x)
    let pann2 = acc("cnn-s_pann_b4_bx4_r2.0_e6");
    let adder = acc("cnn-s_adder_b4_bx4_r2.0_e6");
    if let (Some(p), Some(a)) = (pann2, adder) {
        assert!(p > a, "PANN {p} should beat AdderNet {a} (paper Table 4)");
    }
}
