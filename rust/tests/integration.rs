//! Cross-layer integration tests.
//!
//! Tests that need `make artifacts` outputs skip gracefully when the
//! artifacts are absent, so `cargo test` is green on a fresh clone.

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::data::Dataset;
use pann::experiments::Ctx;
use pann::nn::eval::{batch_tensor, eval_fp32, eval_quantized};
use pann::nn::quantized::{QuantConfig, QuantizedModel};
use pann::nn::Model;
use pann::quant::ActQuantMethod;
use std::path::Path;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("models").join("cnn-s").join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("[skip] artifacts not built");
        None
    }
}

#[test]
fn trained_manifest_loads_and_classifies() {
    let Some(root) = artifacts() else { return };
    let model = Model::load(&root.join("models/cnn-s")).unwrap();
    let ds = Dataset::load(&root.join("data/digits"), "test").unwrap();
    let res = eval_fp32(&model, &ds.take(256)).unwrap();
    assert!(
        res.accuracy() > 0.8,
        "trained cnn-s should classify digits well, got {}",
        res.accuracy()
    );
}

#[test]
fn ptq_pipeline_on_trained_model() {
    let Some(root) = artifacts() else { return };
    let model = Model::load(&root.join("models/cnn-s")).unwrap();
    let ds = Dataset::load(&root.join("data/digits"), "test").unwrap().take(192);
    let calib_ds = Dataset::load(&root.join("data/digits"), "calib").unwrap();
    let calib = batch_tensor(&calib_ds, 0, calib_ds.len());

    // 8-bit unsigned baseline ≈ fp32; 2-bit collapses; PANN at the
    // 2-bit budget recovers (the paper's Table 7 story).
    let fp = eval_fp32(&model, &ds).unwrap();
    let q8 = QuantizedModel::prepare(&model, QuantConfig::unsigned_baseline(8, ActQuantMethod::Aciq), Some(&calib)).unwrap();
    let r8 = eval_quantized(&q8, &ds).unwrap();
    assert!(r8.accuracy() > fp.accuracy() - 0.05, "8-bit {} vs fp {}", r8.accuracy(), fp.accuracy());

    let q2 = QuantizedModel::prepare(&model, QuantConfig::unsigned_baseline(2, ActQuantMethod::Aciq), Some(&calib)).unwrap();
    let r2 = eval_quantized(&q2, &ds).unwrap();

    let pann = QuantizedModel::prepare(
        &model,
        QuantConfig::pann(6, 10.0 / 6.0 - 0.5, ActQuantMethod::Aciq),
        Some(&calib),
    )
    .unwrap();
    let rp = eval_quantized(&pann, &ds).unwrap();
    assert!(
        rp.accuracy() >= r2.accuracy(),
        "PANN {} should beat the 2-bit baseline {}",
        rp.accuracy(),
        r2.accuracy()
    );
    // equal power by construction (both at the 2-bit unsigned budget)
    let ratio = rp.giga_flips / r2.giga_flips;
    assert!(ratio < 1.1, "power ratio {ratio}");
}

#[test]
fn pjrt_fp32_matches_native_engine() {
    let Some(root) = artifacts() else { return };
    let hlo = root.join("hlo");
    if !hlo.join("cnn-s_fp32.hlo.txt").exists() {
        eprintln!("[skip] hlo artifacts not built");
        return;
    }
    use pann::runtime::{ArtifactManifest, CpuRuntime};
    let manifest = ArtifactManifest::load(&hlo).unwrap();
    let spec = manifest
        .executables
        .iter()
        .find(|e| e.model == "cnn-s" && e.variant == "fp32")
        .unwrap();
    let rt = CpuRuntime::new().unwrap();
    let lm = rt.load(&spec.file, &spec.input_shape).unwrap();

    let model = Model::load(&root.join("models/cnn-s")).unwrap();
    let ds = Dataset::load(&root.join("data/digits"), "test").unwrap();
    let x = batch_tensor(&ds, 0, spec.batch);
    let got = lm.run(&x.data).unwrap();
    let want = model.forward(&x).unwrap();
    assert_eq!(got.len(), want.data.len());
    for (a, b) in got.iter().zip(&want.data) {
        assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn pjrt_pann_artifact_classifies() {
    let Some(root) = artifacts() else { return };
    let hlo = root.join("hlo");
    if !hlo.join("manifest.json").exists() {
        eprintln!("[skip] hlo artifacts not built");
        return;
    }
    use pann::runtime::{ArtifactManifest, CpuRuntime};
    let manifest = ArtifactManifest::load(&hlo).unwrap();
    let rt = CpuRuntime::new().unwrap();
    let ds = Dataset::load(&root.join("data/digits"), "test").unwrap();
    for variant in ["pann-p8", "pann-p2"] {
        let spec = manifest
            .executables
            .iter()
            .find(|e| e.model == "cnn-s" && e.variant == variant)
            .unwrap();
        let lm = rt.load(&spec.file, &spec.input_shape).unwrap();
        let mut correct = 0;
        let n = 64;
        for start in (0..n).step_by(spec.batch) {
            let x = batch_tensor(&ds, start, spec.batch);
            let out = lm.run(&x.data).unwrap();
            let classes = out.len() / spec.batch;
            for i in 0..spec.batch {
                let row = &out[i * classes..(i + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == ds.y[start + i] as usize {
                    correct += 1;
                }
            }
        }
        let acc = correct as f64 / n as f64;
        assert!(acc > 0.5, "{variant}: accuracy {acc} too low");
    }
}

#[test]
fn python_rust_pann_quantizers_agree() {
    // The achieved additions budget recorded by aot.py must match the
    // rust PannQuant on the same weights.
    let Some(root) = artifacts() else { return };
    let model = Model::load(&root.join("models/cnn-s")).unwrap();
    let mut all_w = Vec::new();
    for node in &model.nodes {
        if let pann::nn::layers::Op::Conv { w, .. } | pann::nn::layers::Op::Linear { w, .. } =
            &node.op
        {
            all_w.push(w.data.clone());
        }
    }
    assert!(!all_w.is_empty());
    for r in [1.0, 2.5, 7.5] {
        for w in &all_w {
            let pw = pann::quant::pann::PannQuant::new(r).quantize(w);
            assert!(
                (pw.adds_per_element - r).abs() / r < 0.15,
                "R={r} achieved {}",
                pw.adds_per_element
            );
        }
    }
}

#[test]
fn end_to_end_native_serving() {
    // Serve the reference model through the coordinator without PJRT:
    // a local (worker-thread-built) menu behind the one ServerBuilder
    // entry point.
    use pann::coordinator::{EnginePoint, Menu, NativeEngine, ServerBuilder};
    let mut model = Model::reference_cnn(5);
    let ds = Dataset::from_synth(pann::data::synth::digits(96, 6));
    let stats = batch_tensor(&ds, 0, 48);
    model.record_act_stats(&stats).unwrap();
    let srv = ServerBuilder::new()
        .max_batch(8)
        .serve(Menu::local(move || {
            let mut points = Vec::new();
            for (bits, bx, r) in [(2u32, 6u32, 10.0 / 6.0 - 0.5), (8, 8, 7.5)] {
                let qm = QuantizedModel::prepare(
                    &model,
                    QuantConfig::pann(bx, r, ActQuantMethod::BnStats),
                    None,
                )?;
                points.push(EnginePoint {
                    name: format!("p{bits}"),
                    giga_flips_per_sample: pann::power::model::mac_power_unsigned_total(bits)
                        * model.num_macs() as f64
                        / 1e9,
                    engine: Box::new(NativeEngine::new(&qm, 8)),
                });
            }
            Ok(points)
        }))
        .unwrap();
    let client = srv.client();
    assert_eq!(client.sample_len(), 256);
    // unlimited budget -> p8; tight -> p2
    let r = client.infer(ds.sample(0).to_vec()).unwrap();
    assert_eq!(r.point, "p8");
    client.set_budget(0.001);
    let r = client.infer(ds.sample(1).to_vec()).unwrap();
    assert_eq!(r.point, "p2");
    let m = client.metrics();
    assert_eq!(m.requests, 2);
    assert!(m.total_giga_flips > 0.0);
    srv.shutdown();
}

#[test]
fn worker_pool_serves_shared_plans() {
    // The pool path: one Arc<ExecutionPlan> per operating point,
    // shared by 4 workers, each with its own scratch arena. Outputs
    // must match a direct forward through the same plan exactly.
    use pann::coordinator::{Menu, PlanEngine, ServerBuilder, SharedPoint};
    use pann::nn::{Scratch, Tensor};
    use std::sync::Arc;
    let mut model = Model::reference_cnn(7);
    let ds = Dataset::from_synth(pann::data::synth::digits(64, 8));
    let stats = batch_tensor(&ds, 0, 32);
    model.record_act_stats(&stats).unwrap();
    let mut plans = Vec::new();
    let mut points = Vec::new();
    for (bits, bx, r) in [(2u32, 6u32, 10.0 / 6.0 - 0.5), (8, 8, 7.5)] {
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::pann(bx, r, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        let plan = qm.plan();
        plans.push((format!("p{bits}"), plan.clone()));
        points.push(SharedPoint {
            measured_gflips_per_sample: None,
            name: format!("p{bits}"),
            giga_flips_per_sample: pann::power::model::mac_power_unsigned_total(bits)
                * model.num_macs() as f64
                / 1e9,
            engine: Arc::new(PlanEngine::new(plan, 8)),
        });
    }
    let srv = ServerBuilder::new()
        .workers(4)
        .max_batch(8)
        .serve(Menu::shared(points))
        .unwrap();
    let h = srv.client();
    // rich budget -> p8; outputs must equal a direct plan forward
    let want = {
        let plan = &plans.iter().find(|(n, _)| n == "p8").unwrap().1;
        let x = Tensor::new(vec![1, 1, 16, 16], ds.sample(3).to_vec()).unwrap();
        let mut scratch = Scratch::new();
        let mut meter = plan.new_meter();
        plan.forward_batch(&x, &mut scratch, &mut meter, 1).unwrap().data
    };
    let resp = h.infer(ds.sample(3).to_vec()).unwrap();
    assert_eq!(resp.point, "p8");
    assert_eq!(resp.output, want, "pool output diverges from direct plan forward");
    // concurrent clients across the pool
    let total: usize = std::thread::scope(|s| {
        (0..8usize)
            .map(|c| {
                let h = h.clone();
                let ds = &ds;
                s.spawn(move || {
                    let mut ok = 0usize;
                    for i in 0..16usize {
                        let idx = (c * 16 + i) % ds.len();
                        if h.infer(ds.sample(idx).to_vec()).is_ok() {
                            ok += 1;
                        }
                    }
                    ok
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|j| j.join().unwrap())
            .sum()
    });
    assert_eq!(total, 128);
    assert_eq!(h.metrics().requests, 129);
    srv.shutdown();
}

#[test]
fn qos_per_request_caps_and_deadline_on_one_server() {
    // The API-redesign acceptance: two simultaneous clients with
    // different per-request `max_gflips` are served by *different*
    // operating points from the same server, while a third
    // over-deadline request is rejected with
    // `ServeError::DeadlineExceeded` — without being executed.
    use pann::coordinator::{InferRequest, Menu, PlanEngine, ServeError, ServerBuilder, SharedPoint};
    use std::sync::Arc;
    use std::time::Duration;
    let mut model = Model::reference_cnn(21);
    let ds = Dataset::from_synth(pann::data::synth::digits(32, 22));
    let stats = batch_tensor(&ds, 0, 16);
    model.record_act_stats(&stats).unwrap();
    let mut points = Vec::new();
    let mut costs = Vec::new();
    for (bits, bx, r) in [(2u32, 6u32, 10.0 / 6.0 - 0.5), (8, 8, 7.5)] {
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::pann(bx, r, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        let gf = pann::power::model::mac_power_unsigned_total(bits) * model.num_macs() as f64 / 1e9;
        costs.push(gf);
        points.push(SharedPoint {
            measured_gflips_per_sample: None,
            name: format!("p{bits}"),
            giga_flips_per_sample: gf,
            engine: Arc::new(PlanEngine::new(qm.plan(), 8)),
        });
    }
    let (cheap_gf, rich_gf) = (costs[0], costs[1]);
    let srv = ServerBuilder::new()
        .workers(2)
        .max_batch(8)
        .queue_depth(64)
        .budget_gflips(f64::INFINITY)
        .serve(Menu::shared(points))
        .unwrap();
    let client = srv.client();
    // two simultaneous clients, different energy caps
    let (tight, rich) = std::thread::scope(|s| {
        let c1 = client.clone();
        let ds1 = &ds;
        let jt = s.spawn(move || {
            c1.submit(
                InferRequest::new(ds1.sample(0).to_vec()).max_gflips(cheap_gf * 1.01),
            )
            .unwrap()
            .wait()
            .unwrap()
        });
        let c2 = client.clone();
        let ds2 = &ds;
        let jr = s.spawn(move || {
            c2.submit(
                InferRequest::new(ds2.sample(1).to_vec()).max_gflips(rich_gf * 1.01),
            )
            .unwrap()
            .wait()
            .unwrap()
        });
        (jt.join().unwrap(), jr.join().unwrap())
    });
    assert_eq!(tight.point, "p2", "capped request must take the cheap point");
    assert_eq!(rich.point, "p8", "generous cap must take the rich point");
    assert!(tight.giga_flips < rich.giga_flips);
    // the third request is already past its deadline: typed rejection
    let e = client
        .submit(InferRequest::new(ds.sample(2).to_vec()).deadline(Duration::ZERO))
        .unwrap()
        .wait()
        .unwrap_err();
    assert_eq!(e, ServeError::DeadlineExceeded);
    let m = client.metrics();
    assert_eq!(m.requests, 2, "the expired request must not be executed");
    assert_eq!(m.expired, 1);
    srv.shutdown();
}

#[test]
fn menu_compile_serialize_serve_roundtrip() {
    // The menu-compiler acceptance: compile the frontier, persist it
    // as menu.json, reload it through `Menu::from_artifact`, and serve
    // it — a client sweeping `max_gflips` across the frontier must
    // land on each point in turn, with monotone non-decreasing
    // recorded validation accuracy (the paper's deployment-time
    // traversal over a *compiled* menu).
    use pann::coordinator::{InferRequest, Menu, ServerBuilder};
    use pann::pann::{compile_menu, MenuArtifact};
    let mut model = Model::reference_cnn(31);
    let ds = Dataset::from_synth(pann::data::synth::digits(96, 32));
    let stats = batch_tensor(&ds, 0, 48);
    model.record_act_stats(&stats).unwrap();
    let val = ds.take(64);
    let art =
        compile_menu(&model, &[2, 4, 8], ActQuantMethod::BnStats, None, &val, 2..=8).unwrap();
    assert!(!art.points.is_empty());
    assert!(art.swept >= art.points.len());

    // serialize -> load: identical artifact
    let dir = std::env::temp_dir().join("pann_test_menu_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("menu.json");
    art.save(&path).unwrap();
    let loaded = MenuArtifact::load(&path).unwrap();
    assert_eq!(loaded, art);

    // a different model is rejected by the fingerprint check when the
    // deferred menu builds its engines at serve time
    let other = Model::reference_cnn(99);
    let bad = Menu::from_artifact(&path, &other).unwrap();
    assert!(
        ServerBuilder::new().serve(bad).is_err(),
        "serving a menu against the wrong model must fail"
    );

    // serve the reloaded menu and sweep the frontier via per-request caps
    let menu = Menu::from_artifact(&path, &model).unwrap();
    let srv = ServerBuilder::new().workers(2).max_batch(8).serve(menu).unwrap();
    let client = srv.client();
    let mut last_acc = -1.0f64;
    for p in &loaded.points {
        let r = client
            .submit(
                InferRequest::new(ds.sample(0).to_vec())
                    .max_gflips(p.gflips_per_sample * (1.0 + 1e-9)),
            )
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(
            r.point, p.name,
            "cap {} must land on frontier point {}",
            p.gflips_per_sample, p.name
        );
        assert!(
            p.val_acc > last_acc,
            "frontier accuracy must increase with budget: {} then {}",
            last_acc,
            p.val_acc
        );
        last_acc = p.val_acc;
    }
    // a cap below the cheapest point falls back to the cheapest
    let r = client
        .submit(
            InferRequest::new(ds.sample(1).to_vec())
                .max_gflips(loaded.points[0].gflips_per_sample * 0.5),
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(r.point, loaded.points[0].name);
    srv.shutdown();
}

#[test]
fn batched_engine_matches_per_sample_path() {
    // Acceptance criterion of the plan/exec refactor: the batched,
    // blocked, multi-threaded engine produces bit-identical logits and
    // bit-flip totals to the per-sample path, on both reference
    // architectures and on signed/unsigned/PANN arithmetic.
    use pann::nn::{Scratch, Tensor};
    for model in [Model::reference_cnn(11), Model::reference_resnet(12)] {
        let mut model = model;
        let ds = Dataset::from_synth(pann::data::synth::digits(16, 13));
        let x = batch_tensor(&ds, 0, 16);
        model.record_act_stats(&x).unwrap();
        let calib = batch_tensor(&ds, 0, 8);
        for cfg in [
            QuantConfig::signed_baseline(6, ActQuantMethod::Aciq),
            QuantConfig::unsigned_baseline(4, ActQuantMethod::Aciq),
            QuantConfig::pann(6, 2.0, ActQuantMethod::Aciq),
        ] {
            let qm = QuantizedModel::prepare(&model, cfg, Some(&calib)).unwrap();
            let plan = qm.plan();
            let mut scratch = Scratch::for_plan(&plan, 16);
            let mut meter_b = plan.new_meter();
            let batched = plan
                .forward_batch(&x, &mut scratch, &mut meter_b, pann::nn::eval::n_threads())
                .unwrap();
            let classes = batched.sample_len();
            let mut meter_s = plan.new_meter();
            for i in 0..16 {
                let xi = Tensor::new(vec![1, 1, 16, 16], x.sample(i).to_vec()).unwrap();
                let yi = plan.forward_batch(&xi, &mut scratch, &mut meter_s, 1).unwrap();
                assert_eq!(
                    yi.data,
                    &batched.data[i * classes..(i + 1) * classes],
                    "{}: sample {i} logits diverge",
                    model.name
                );
            }
            assert_eq!(meter_b.total_macs(), meter_s.total_macs());
            let (fb, fs) = (meter_b.total_flips(), meter_s.total_flips());
            assert!(
                (fb - fs).abs() <= 1e-9 * fb.abs().max(1.0),
                "{}: flip totals diverge: {fb} vs {fs}",
                model.name
            );
        }
    }
}

#[test]
fn experiment_registry_complete() {
    // every experiment id in DESIGN.md's index exists
    let ids = pann::experiments::ids();
    for want in [
        "table1", "table2", "table4", "table5", "table6", "table7", "table8", "table9",
        "table10", "table11", "table12", "table13", "table14", "table15", "fig1", "fig3",
        "fig4", "fig5", "fig6", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14",
        "fig16",
    ] {
        assert!(ids.contains(&want), "missing experiment {want}");
    }
}

#[test]
fn qat_results_present_and_ordered() {
    let Some(root) = artifacts() else { return };
    let ctx = Ctx { artifacts: root.to_path_buf(), quick: true };
    let Some(results) = ctx.qat_results() else {
        eprintln!("[skip] qat_results.json missing");
        return;
    };
    let acc = |k: &str| results.get(k).and_then(|v| v.get("acc")).and_then(|v| v.as_f64());
    // Table 4 ordering at 4/4 on cnn-s: PANN(2x) > AdderNet(2x)
    let pann2 = acc("cnn-s_pann_b4_bx4_r2.0_e6");
    let adder = acc("cnn-s_adder_b4_bx4_r2.0_e6");
    if let (Some(p), Some(a)) = (pann2, adder) {
        assert!(p > a, "PANN {p} should beat AdderNet {a} (paper Table 4)");
    }
}

#[test]
fn governor_load_ramp_walks_frontier_down_and_back() {
    // The closed-loop acceptance: with an energy envelope set, a
    // synthetic load ramp must walk the served point *down* the
    // frontier (sustained load would otherwise blow the envelope),
    // and an idle period must climb back to the most accurate point.
    // Without an envelope, the very same menu serves open-loop
    // exactly as in PR 3: the budget cell never moves on its own.
    use pann::coordinator::{
        BatchEngine, EnergyEnvelope, InferRequest, Menu, ServerBuilder, SharedPoint,
    };
    use pann::nn::Scratch;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    /// Constant-output engine: the ramp needs controlled costs, not a
    /// real network (those are covered by the serve_menu tests).
    struct FixedEngine;
    impl BatchEngine for FixedEngine {
        fn max_batch(&self) -> usize {
            8
        }
        fn sample_len(&self) -> usize {
            3
        }
        fn infer_batch(
            &self,
            _x: &[f32],
            n: usize,
            _scratch: &mut Scratch,
        ) -> anyhow::Result<Vec<f32>> {
            Ok(vec![0.0; n * 2])
        }
    }

    let points = |costs: &[(&str, f64)]| -> Vec<SharedPoint> {
        costs
            .iter()
            .map(|&(name, gf)| SharedPoint {
                measured_gflips_per_sample: None,
                name: name.into(),
                giga_flips_per_sample: gf,
                engine: Arc::new(FixedEngine),
            })
            .collect()
    };
    let frontier = [("cheap", 0.1), ("mid", 1.0), ("rich", 10.0)];

    // open-loop control: identical menu, no envelope -> no governor,
    // and the served point never moves without a client budget change
    let open = ServerBuilder::new()
        .workers(1)
        .serve(Menu::shared(points(&frontier)))
        .unwrap();
    let oc = open.client();
    assert!(oc.governor().is_none());
    for _ in 0..20 {
        assert_eq!(oc.infer(vec![0.0; 3]).unwrap().point, "rich");
    }
    assert_eq!(oc.budget(), f64::INFINITY, "open-loop budget cell must not move");
    open.shutdown();

    // closed loop: envelope of 60 GF/s over 10 ms windows = 0.6
    // GF/window. A single "rich" request (10 GF) breaches; "mid"
    // breaches under flood; "cheap" fits.
    let srv = ServerBuilder::new()
        .workers(1)
        .max_batch(4)
        .max_wait(Duration::from_micros(200))
        .envelope(EnergyEnvelope::gflips_per_sec(60.0))
        .governor_window(Duration::from_millis(10))
        .governor_hysteresis(1)
        .serve(Menu::shared(points(&frontier)))
        .unwrap();
    let c = srv.client();
    assert!(c.governor().is_some());
    // the governor normalizes the infinite default budget onto the top
    // frontier point
    assert_eq!(c.budget(), 10.0);

    // ramp up: flood until the served point has walked to the floor,
    // passing through at least one intermediate observation
    let t0 = Instant::now();
    let mut seen = Vec::<String>::new();
    let mut reached_floor = false;
    while t0.elapsed() < Duration::from_secs(20) {
        let p = c.infer(vec![0.0; 3]).unwrap().point;
        if seen.last() != Some(&p) {
            seen.push(p.clone());
        }
        if p == "cheap" {
            reached_floor = true;
            break;
        }
    }
    assert!(reached_floor, "sustained load never walked the frontier down: {seen:?}");
    assert_eq!(seen.first().map(String::as_str), Some("rich"));
    assert!(
        seen.contains(&"mid".to_string()),
        "degradation must step through the frontier, not jump: {seen:?}"
    );

    // ramp down: an idle period must climb back to the most accurate
    // point — the first probe closes the idle windows (and is still
    // served at the floor), the next one sees the recovered budget.
    // timing-sensitive: the idle gap must cover two full climb
    // horizons (hysteresis * window per step) with slack for a loaded
    // CI box; the deterministic version of this walk runs under the
    // injected clock in tests/scenarios.rs
    std::thread::sleep(Duration::from_millis(200));
    let _ = c.infer(vec![0.0; 3]).unwrap();
    let recovered = c.infer(vec![0.0; 3]).unwrap().point;
    assert_eq!(recovered, "rich", "idle period must recover full accuracy");

    let g = c.governor().unwrap();
    assert!(g.switches >= 3, "down 2 + up 2 steps expected, saw {}", g.switches);
    assert!(g.windows > 0);
    let resid_total: u64 = g.residency.iter().map(|(_, w)| w).sum();
    assert_eq!(resid_total, g.windows, "every closed window belongs to one point");
    // the synthetic engines meter nothing: the calibration ledger must
    // say so rather than invent numbers from the modeled fallback
    assert!(g.measured_gflips_per_sample.iter().all(|(_, m)| m.is_none()));
    let m = c.metrics();
    assert!(m.point_switches >= 3);
    srv.shutdown();
}

#[test]
fn fleet_two_models_one_envelope_hot_degrades_cold_holds() {
    // The fleet acceptance: two clients hit two *registered* models
    // under one shared envelope. The hot (flooding) model must step
    // down its own frontier; the cold (paced) model's operating point
    // must never move; and a single-model ServerBuilder run over the
    // same menu stays behaviorally identical to the PR-4 server.
    use pann::coordinator::{
        BatchEngine, EnergyEnvelope, InferRequest, Menu, ServerBuilder, SharedPoint,
    };
    use pann::nn::Scratch;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    /// Constant-output engine: the arbitration logic needs controlled
    /// costs, not a real network (real compiled menus are covered by
    /// the serve_menu and fleet bench paths).
    struct FixedEngine;
    impl BatchEngine for FixedEngine {
        fn max_batch(&self) -> usize {
            8
        }
        fn sample_len(&self) -> usize {
            3
        }
        fn infer_batch(
            &self,
            _x: &[f32],
            n: usize,
            _scratch: &mut Scratch,
        ) -> anyhow::Result<Vec<f32>> {
            Ok(vec![0.0; n * 2])
        }
    }

    let menu = |costs: &[(&str, f64)]| -> Menu {
        Menu::shared(
            costs
                .iter()
                .map(|&(name, gf)| SharedPoint {
                    measured_gflips_per_sample: None,
                    name: name.into(),
                    giga_flips_per_sample: gf,
                    engine: Arc::new(FixedEngine),
                })
                .collect(),
        )
    };
    // hot's frontier is orders of magnitude pricier than cold's whole
    // menu, so any realistic probe rate keeps cold's demand-need far
    // inside the 50 GF/s envelope while hot's flood blows it.
    let hot_frontier = [("h-cheap", 0.1), ("h-mid", 1.0), ("h-rich", 10.0)];
    let cold_frontier = [("c-cheap", 0.0001), ("c-rich", 0.001)];

    let srv = ServerBuilder::new()
        .workers(2)
        .max_batch(4)
        .max_wait(Duration::from_micros(200))
        .envelope(EnergyEnvelope::gflips_per_sec(50.0))
        .governor_window(Duration::from_millis(10))
        .governor_hysteresis(1)
        .register("hot", menu(&hot_frontier))
        .register("cold", menu(&cold_frontier))
        .serve_fleet()
        .unwrap();
    let c = srv.client();
    assert_eq!(c.models(), vec!["hot", "cold"]);

    // two clients, concurrently: one floods hot, one paces cold
    let (hot_walk, cold_points) = std::thread::scope(|s| {
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let hc = c.clone();
        let hd = done.clone();
        let hot = s.spawn(move || {
            let t0 = Instant::now();
            let mut walk = Vec::<String>::new();
            while t0.elapsed() < Duration::from_secs(20) {
                let p = hc
                    .submit(InferRequest::new(vec![0.0; 3]).model("hot"))
                    .unwrap()
                    .wait()
                    .unwrap()
                    .point;
                if walk.last() != Some(&p) {
                    walk.push(p.clone());
                }
                if p == "h-cheap" {
                    break;
                }
            }
            hd.store(true, std::sync::atomic::Ordering::SeqCst);
            walk
        });
        let cc = c.clone();
        let cold = s.spawn(move || {
            let mut points = Vec::new();
            while !done.load(std::sync::atomic::Ordering::SeqCst) {
                let r = cc
                    .submit(InferRequest::new(vec![0.0; 3]).model("cold"))
                    .unwrap()
                    .wait()
                    .unwrap();
                assert_eq!(r.model.as_deref(), Some("cold"));
                points.push(r.point);
                // timing-sensitive: pacing >= the governor window
                // bounds how many cold requests can ever bunch into
                // one decision window, so the demand headroom always
                // covers the worst burst (the deterministic tenant
                // isolation story is tests/scenarios.rs)
                std::thread::sleep(Duration::from_millis(10));
            }
            points
        });
        (hot.join().unwrap(), cold.join().unwrap())
    });

    assert_eq!(
        hot_walk.last().map(String::as_str),
        Some("h-cheap"),
        "hot model never reached its frontier floor: {hot_walk:?}"
    );
    assert_eq!(hot_walk.first().map(String::as_str), Some("h-rich"));
    assert!(
        cold_points.iter().all(|p| p == "c-rich"),
        "cold model's point must not move: {cold_points:?}"
    );
    // per-model governors: hot stepped, cold did not
    let gh = c.model_governor("hot").unwrap();
    let gc = c.model_governor("cold").unwrap();
    assert!(gh.switches >= 1);
    assert_eq!(gh.point, "h-cheap");
    assert_eq!(gc.switches, 0, "cold governor must never have stepped");
    assert_eq!(gc.point, "c-rich");
    // metrics are model-qualified: both models' counters are separate
    let per: std::collections::BTreeMap<_, _> = c.metrics().per_point.iter().cloned().collect();
    assert!(per.keys().all(|k| k.starts_with("hot:") || k.starts_with("cold:")), "{per:?}");
    assert!(per.get("cold:c-rich").is_some_and(|&n| n > 0));
    // the fleet snapshot exposes the arbitration: shares sum to the
    // envelope, cold's demand estimate is the smaller one
    let fleet = c.fleet().unwrap();
    let share: f64 = fleet.models.iter().map(|m| m.envelope_share.unwrap()).sum();
    assert!((share - 50.0).abs() < 1e-6, "shares must sum to the envelope, got {share}");
    srv.shutdown();

    // single-model control: the same hot menu behind the PR-4 `serve`
    // path — no registry anywhere: bare point keys, no model echo, the
    // fleet accessors empty, open-loop budget cell untouched
    let single = ServerBuilder::new()
        .workers(1)
        .serve(menu(&hot_frontier))
        .unwrap();
    let sc = single.client();
    let r = sc.infer(vec![0.0; 3]).unwrap();
    assert_eq!(r.point, "h-rich");
    assert_eq!(r.model, None, "single-model responses must not carry a model");
    assert!(sc.models().is_empty() && sc.fleet().is_none() && sc.governor().is_none());
    assert_eq!(sc.budget(), f64::INFINITY);
    let per: Vec<String> = sc.metrics().per_point.iter().map(|(k, _)| k.clone()).collect();
    assert_eq!(per, vec!["h-rich".to_string()], "single-model keys must stay bare");
    single.shutdown();
}

#[test]
fn governed_real_menu_serves_with_measured_energy() {
    // Closed loop over a *real* compiled menu: the plan-backed engines
    // meter actual flips, so responses carry measured energy and the
    // governor's ledger fills with measured (not modeled) costs.
    use pann::coordinator::{EnergyEnvelope, Menu, ServerBuilder};
    use pann::pann::compile_menu;
    let mut model = Model::reference_cnn(41);
    let ds = Dataset::from_synth(pann::data::synth::digits(64, 42));
    let stats = batch_tensor(&ds, 0, 32);
    model.record_act_stats(&stats).unwrap();
    let art = compile_menu(&model, &[2, 8], ActQuantMethod::BnStats, None, &ds.take(32), 2..=6)
        .unwrap();
    let srv = ServerBuilder::new()
        .workers(2)
        .max_batch(4)
        .envelope(EnergyEnvelope::gflips_per_sec(1e6)) // generous: no stepping needed
        .serve(Menu::shared(art.shared_points(&model, None, 4).unwrap()))
        .unwrap();
    let client = srv.client();
    for i in 0..16 {
        let r = client.infer(ds.sample(i).to_vec()).unwrap();
        let measured = r.measured_gflips.expect("plan engines meter flips");
        assert!(measured > 0.0);
    }
    let g = client.governor().unwrap();
    // the served (top) point has a measured cost in the ledger
    let top = g.measured_gflips_per_sample.last().unwrap();
    assert!(top.1.is_some(), "ledger must hold measured GF/sample for the served point");
    assert!(top.1.unwrap() > 0.0);
    let m = client.metrics();
    assert!(m.measured_giga_flips > 0.0);
    // measured and modeled agree on the compiled menu (the artifact's
    // costs *are* metered costs), so the delta stays small relative
    // to the total
    assert!(
        m.measured_minus_modeled_gflips.abs() <= m.measured_giga_flips * 0.05,
        "measured {} vs delta {}",
        m.measured_giga_flips,
        m.measured_minus_modeled_gflips
    );
    srv.shutdown();
}

#[test]
fn net_edge_serves_the_frontier_over_loopback() {
    // The network-edge acceptance: the same frontier that answers
    // in-process QoS (see `qos_per_request_caps_and_deadline_on_one_
    // server`) must answer it over a socket — two concurrent HTTP
    // clients with different `max_gflips` caps are served by different
    // operating points from a 2-shard edge, wire-level failures map to
    // their HTTP statuses, and /metrics exposes per-shard residency.
    use pann::coordinator::{PlanEngine, Server, ServerBuilder, SharedPoint};
    use pann::net::{NetConfig, NetServer, ShardRouter};
    use pann::util::Json;
    use std::io::{Read, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::sync::Arc;

    /// One raw HTTP/1.1 exchange (Connection: close) -> (status, body).
    fn call(addr: SocketAddr, raw: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let status: u16 = buf.split_whitespace().nth(1).expect("status line").parse().unwrap();
        let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }
    fn post_infer(addr: SocketAddr, json: &str) -> (u16, String) {
        call(
            addr,
            &format!(
                "POST /v1/infer HTTP/1.1\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{}",
                json.len(),
                json
            ),
        )
    }

    let mut model = Model::reference_cnn(21);
    let ds = Dataset::from_synth(pann::data::synth::digits(32, 22));
    let stats = batch_tensor(&ds, 0, 16);
    model.record_act_stats(&stats).unwrap();
    // two frontier points; engines compiled once, plans Arc-shared
    // into per-shard SharedPoint vectors (SharedPoint itself is not
    // Clone — each shard gets its own)
    let mut compiled = Vec::new();
    for (bits, bx, r) in [(2u32, 6u32, 10.0 / 6.0 - 0.5), (8, 8, 7.5)] {
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::pann(bx, r, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        let gf = pann::power::model::mac_power_unsigned_total(bits) * model.num_macs() as f64 / 1e9;
        compiled.push((format!("p{bits}"), gf, qm.plan()));
    }
    let (cheap_gf, rich_gf) = (compiled[0].1, compiled[1].1);
    let router = ShardRouter::builder()
        .build(2, |_, _| -> anyhow::Result<Server> {
            let points = compiled
                .iter()
                .map(|(name, gf, plan)| SharedPoint {
                    measured_gflips_per_sample: None,
                    name: name.clone(),
                    giga_flips_per_sample: *gf,
                    engine: Arc::new(PlanEngine::new(plan.clone(), 8)),
                })
                .collect();
            Ok(ServerBuilder::new()
                .workers(1)
                .max_batch(8)
                .queue_depth(64)
                .budget_gflips(f64::INFINITY)
                .serve(pann::coordinator::Menu::shared(points))?)
        })
        .unwrap();
    let srv = NetServer::bind(
        "127.0.0.1:0",
        router,
        NetConfig { handler_threads: 3, ..NetConfig::default() },
    )
    .unwrap();
    let addr = srv.local_addr();

    // two concurrent clients at different energy caps: different
    // operating points over the same socket
    fn body_json(sample: &[f32], cap: f64) -> String {
        let nums: Vec<String> = sample.iter().map(|x| format!("{x}")).collect();
        format!(r#"{{"input": [{}], "max_gflips": {cap}}}"#, nums.join(","))
    }
    let (tight, rich) = std::thread::scope(|s| {
        let jt = s.spawn(|| post_infer(addr, &body_json(ds.sample(0), cheap_gf * 1.01)));
        let jr = s.spawn(|| post_infer(addr, &body_json(ds.sample(1), rich_gf * 1.01)));
        (jt.join().unwrap(), jr.join().unwrap())
    });
    assert_eq!(tight.0, 200, "{}", tight.1);
    assert_eq!(rich.0, 200, "{}", rich.1);
    let tight = Json::parse(&tight.1).unwrap();
    let rich = Json::parse(&rich.1).unwrap();
    assert_eq!(tight.get("point").unwrap().as_str(), Some("p2"), "capped -> cheap point");
    assert_eq!(rich.get("point").unwrap().as_str(), Some("p8"), "generous -> rich point");
    assert!(
        tight.get("giga_flips").unwrap().as_f64().unwrap()
            < rich.get("giga_flips").unwrap().as_f64().unwrap()
    );

    // wire-level failure mapping
    let (status, _) = post_infer(addr, "{definitely not json");
    assert_eq!(status, 400);
    let (status, body) = post_infer(addr, &body_json(ds.sample(2), 1e9).replace(
        "\"max_gflips\"",
        "\"pin\": \"ghost\", \"max_gflips\"",
    ));
    assert_eq!(status, 404, "{body}");
    assert!(body.contains("unknown_point"), "{body}");
    let expired: Vec<String> = ds.sample(3).iter().map(|x| format!("{x}")).collect();
    let (status, body) = post_infer(
        addr,
        &format!(r#"{{"input": [{}], "deadline_ms": 0}}"#, expired.join(",")),
    );
    assert_eq!(status, 408, "{body}");
    assert!(body.contains("deadline_exceeded"), "{body}");

    // shard residency is visible on /metrics
    let (status, metrics) = call(addr, "GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_eq!(status, 200);
    for line in [
        "pann_http_requests_total",
        "pann_shard_requests_total{shard=\"0\"}",
        "pann_shard_requests_total{shard=\"1\"}",
        "pann_shard_shed_total{shard=\"0\"}",
    ] {
        assert!(metrics.contains(line), "missing {line} in:\n{metrics}");
    }
    // both 200-served requests landed somewhere
    let served: u64 = metrics
        .lines()
        .filter(|l| l.starts_with("pann_shard_requests_total"))
        .map(|l| l.split_whitespace().last().unwrap().parse::<u64>().unwrap())
        .sum();
    assert!(served >= 2, "at least the two 200s must be admitted, metrics:\n{metrics}");

    // the model surface answers over the wire too
    let (status, body) = call(addr, "GET /v1/models HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("shards").unwrap().as_usize(), Some(2));
    assert_eq!(j.get("sample_len").unwrap().as_usize(), Some(ds.sample(0).len()));

    srv.shutdown();
}

#[test]
fn overflow_unsafe_fixture_parses_but_never_compiles() {
    // the committed fixture `pann-cli verify` must reject (CI asserts
    // exit code 2 on it): it parses as a valid pann-menu/v2 artifact —
    // the loader checks structure, not soundness — but its declared
    // widths are exactly the ones the plan compiler refuses, so the
    // static audit and the compiler agree on the verdict
    let path = Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/menu-overflow-unsafe.json"
    ));
    let menu = pann::pann::MenuArtifact::load(path).expect("fixture must stay parseable");
    let p = &menu.points[0];
    assert!(p.bx_tilde > 31, "fixture must declare an unrepresentable act width");
    assert!(p.weight_code_bits > 31, "fixture must declare an unrepresentable weight width");

    let mut model = Model::reference_cnn(7);
    model
        .record_act_stats(&batch_tensor(
            &Dataset::from_synth(pann::data::synth::digits(64, 11)),
            0,
            32,
        ))
        .unwrap();
    let cfg = QuantConfig::pann(p.bx_tilde, p.r, p.quant_method);
    let err = pann::nn::ExecutionPlan::compile(&model, cfg, None)
        .err()
        .expect("a 32-bit dynamic activation hull cannot fit the i32 operand slab");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("i32") || msg.contains("32"),
        "rejection should cite the width: {msg}"
    );
}

#[test]
fn mixed_unsafe_fixture_is_rejected_at_load() {
    // unlike the v2 overflow fixture (which parses and is rejected by
    // the static audit, exit 2), an out-of-range per-layer width is a
    // malformed artifact: the v3 loader refuses it outright, so
    // `pann-cli verify` exits 1 before any audit runs (CI asserts both
    // the exit code and that the error names layer_bits)
    let path = Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/menu-mixed-unsafe.json"
    ));
    let err = pann::pann::MenuArtifact::load(path).expect_err("fixture must not load");
    let msg = format!("{err:#}");
    assert!(msg.contains("layer_bits"), "{msg}");
    assert!(msg.contains("1..=31"), "{msg}");
}
