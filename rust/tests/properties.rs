//! Hand-rolled property tests (the offline registry carries no
//! proptest): randomized invariants over quantizers, the unsigned
//! split, power models and the toggle simulators.

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::bitflip::{BoothMultiplier, Multiplier, SerialMultiplier};
use pann::nn::gemm;
use pann::quant::pann::PannQuant;
use pann::quant::ruq;
use pann::util::Rng;

const CASES: usize = 200;

#[test]
fn prop_quantize_dequantize_error_bounded() {
    let mut rng = Rng::new(101);
    for _ in 0..CASES {
        let n = 1 + rng.below(256);
        let scale = (rng.f32() + 0.01) * 3.0;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * scale).collect();
        let bits = 2 + rng.below(7) as u32;
        let q = ruq::fit_signed(&xs, bits);
        for &x in &xs {
            let e = (x - q.dequantize(q.quantize(x))).abs();
            assert!(e <= 0.5 * q.scale + 1e-5, "bits={bits} x={x} err={e} step={}", q.scale);
        }
    }
}

#[test]
fn prop_pann_codes_reconstruct_within_half_gamma() {
    let mut rng = Rng::new(102);
    for _ in 0..CASES {
        let n = 1 + rng.below(512);
        let r = 0.5 + rng.f64() * 7.5;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let pw = PannQuant::new(r).quantize(&xs);
        for (i, &x) in xs.iter().enumerate() {
            assert!((x - pw.dequant(i)).abs() <= 0.5 * pw.gamma + 1e-5);
        }
        // L1 budget is never exceeded by more than rounding slack
        assert!(pw.adds_per_element <= r + 0.5 + 1e-9, "R={r} got {}", pw.adds_per_element);
    }
}

#[test]
fn prop_unsigned_split_gemm_exact() {
    let mut rng = Rng::new(103);
    for _ in 0..60 {
        let m = 1 + rng.below(12);
        let n = 1 + rng.below(12);
        let k = 1 + rng.below(48);
        let a: Vec<i32> = (0..m * k).map(|_| rng.range_i64(0, 256) as i32).collect();
        let w: Vec<i32> = (0..n * k).map(|_| rng.range_i64(-100, 100) as i32).collect();
        let pos: Vec<i32> = w.iter().map(|&v| v.max(0)).collect();
        let neg: Vec<i32> = w.iter().map(|&v| (-v).max(0)).collect();
        let mut out_a = vec![0i64; m * n];
        let mut out_b = vec![0i64; m * n];
        gemm::gemm_i32(&a, &w, &mut out_a, m, n, k);
        gemm::gemm_i32_split(&a, &pos, &neg, &mut out_b, m, n, k);
        assert_eq!(out_a, out_b);
    }
}

#[test]
fn prop_blocked_threaded_gemm_bit_exact() {
    // The blocked/threaded kernels must match their scalar references
    // bit-exactly across narrow/wide × split/unified variants, odd
    // m/n/k sizes (straddling every tile boundary) and thread counts.
    let mut rng = Rng::new(110);
    for _ in 0..25 {
        let m = 1 + rng.below(80);
        let n = 1 + rng.below(70);
        let k = 1 + rng.below(300);
        let threads = 1 + rng.below(6);
        let a: Vec<i32> = (0..m * k).map(|_| rng.range_i64(0, 256) as i32).collect();
        let w: Vec<i32> = (0..n * k).map(|_| rng.range_i64(-127, 128) as i32).collect();
        let pos: Vec<i32> = w.iter().map(|&v| v.max(0)).collect();
        let neg: Vec<i32> = w.iter().map(|&v| (-v).max(0)).collect();
        let mut want = vec![0i64; m * n];
        let mut got = vec![0i64; m * n];

        gemm::gemm_i32(&a, &w, &mut want, m, n, k);
        gemm::gemm_i32_blocked(&a, &w, &mut got, m, n, k, threads);
        assert_eq!(want, got, "wide m={m} n={n} k={k} t={threads}");

        gemm::gemm_i32_narrow(&a, &w, &mut want, m, n, k);
        gemm::gemm_i32_narrow_blocked(&a, &w, &mut got, m, n, k, threads);
        assert_eq!(want, got, "narrow m={m} n={n} k={k} t={threads}");

        gemm::gemm_i32_split(&a, &pos, &neg, &mut want, m, n, k);
        gemm::gemm_i32_split_blocked(&a, &pos, &neg, &mut got, m, n, k, threads);
        assert_eq!(want, got, "split m={m} n={n} k={k} t={threads}");

        gemm::gemm_i32_split_narrow(&a, &pos, &neg, &mut want, m, n, k);
        gemm::gemm_i32_split_narrow_blocked(&a, &pos, &neg, &mut got, m, n, k, threads);
        assert_eq!(want, got, "split-narrow m={m} n={n} k={k} t={threads}");
    }
}

#[test]
fn prop_simd_kernels_bit_identical_to_scalar() {
    // Every SIMD dispatch level must reproduce the scalar reference
    // kernels bit-for-bit, for all four variants, across odd shapes
    // (straddling vector-width and tile boundaries), thread counts,
    // and two input regimes: realistic quantization codes, and
    // full-range i32 values that drive the narrow (wrapping) paths
    // deep into wrap-around.
    use pann::nn::gemm::{active_level, SimdLevel};
    let levels = [SimdLevel::Scalar, active_level()];
    let mut rng = Rng::new(120);
    for case in 0..30 {
        let m = 1 + rng.below(40);
        let n = 1 + rng.below(35);
        let k = 1 + rng.below(200);
        let threads = 1 + rng.below(4);
        let wild = case % 2 == 1; // alternate realistic / wrap-around
        let (alo, ahi, wlo, whi) = if wild {
            (i32::MIN as i64, i32::MAX as i64 + 1, i32::MIN as i64, i32::MAX as i64 + 1)
        } else {
            (0, 256, -127, 128)
        };
        let a: Vec<i32> = (0..m * k).map(|_| rng.range_i64(alo, ahi) as i32).collect();
        let w: Vec<i32> = (0..n * k).map(|_| rng.range_i64(wlo, whi) as i32).collect();
        let pos: Vec<i32> = w.iter().map(|&v| v.max(0)).collect();
        let neg: Vec<i32> = w.iter().map(|&v| (-v).max(0)).collect();
        let mut want = vec![0i64; m * n];
        let mut got = vec![0i64; m * n];

        gemm::gemm_i32_narrow(&a, &w, &mut want, m, n, k);
        for level in levels {
            gemm::gemm_i32_narrow_blocked_at(level, &a, &w, &mut got, m, n, k, threads);
            assert_eq!(want, got, "narrow {level:?} m={m} n={n} k={k} t={threads} wild={wild}");
        }

        gemm::gemm_i32_split_narrow(&a, &pos, &neg, &mut want, m, n, k);
        for level in levels {
            gemm::gemm_i32_split_narrow_blocked_at(level, &a, &pos, &neg, &mut got, m, n, k, threads);
            assert_eq!(want, got, "split-narrow {level:?} m={m} n={n} k={k} t={threads}");
        }

        // The wide kernels' contract requires |Σ a·w| within i64 — the
        // realistic regime; skip them on wild inputs where even the
        // scalar reference's i64 chain may wrap (UB-free but
        // unspecified by the kernel contract).
        if !wild {
            gemm::gemm_i32(&a, &w, &mut want, m, n, k);
            for level in levels {
                gemm::gemm_i32_blocked_at(level, &a, &w, &mut got, m, n, k, threads);
                assert_eq!(want, got, "wide {level:?} m={m} n={n} k={k} t={threads}");
            }

            gemm::gemm_i32_split(&a, &pos, &neg, &mut want, m, n, k);
            for level in levels {
                gemm::gemm_i32_split_blocked_at(level, &a, &pos, &neg, &mut got, m, n, k, threads);
                assert_eq!(want, got, "split-wide {level:?} m={m} n={n} k={k} t={threads}");
            }
        }
    }
}

#[test]
fn prop_packed_kernel_matches_widened_narrow() {
    // The packed i16 kernel is the narrow kernel over widened codes:
    // bit-identical for all i16 inputs, including accumulator
    // wrap-around (full-range i16 products overflow i32 within a few
    // hundred terms), at every dispatch level and thread count.
    use pann::nn::gemm::{active_level, SimdLevel};
    let levels = [SimdLevel::Scalar, active_level()];
    let mut rng = Rng::new(121);
    for case in 0..30 {
        let m = 1 + rng.below(30);
        let n = 1 + rng.below(25);
        let k = 1 + rng.below(400);
        let threads = 1 + rng.below(4);
        let (lo, hi) = if case % 2 == 1 {
            (i16::MIN as i64, i16::MAX as i64 + 1)
        } else {
            (0, 64) // realistic narrow codes
        };
        let a16: Vec<i16> = (0..m * k).map(|_| rng.range_i64(lo, hi) as i16).collect();
        let w16: Vec<i16> = (0..n * k).map(|_| rng.range_i64(lo.min(-63), hi) as i16).collect();
        let a32: Vec<i32> = a16.iter().map(|&v| v as i32).collect();
        let w32: Vec<i32> = w16.iter().map(|&v| v as i32).collect();
        let mut want = vec![0i64; m * n];
        let mut got = vec![0i64; m * n];
        gemm::gemm_i32_narrow(&a32, &w32, &mut want, m, n, k);
        for level in levels {
            gemm::gemm_i16_narrow_blocked_at(level, &a16, &w16, &mut got, m, n, k, threads);
            assert_eq!(want, got, "packed {level:?} m={m} n={n} k={k} t={threads}");
        }
    }
}

#[test]
fn prop_forced_scalar_hatches_pin_dispatch() {
    // When either escape hatch is engaged — the `force-scalar` cargo
    // feature (CI fallback leg) or PANN_FORCE_SCALAR in the
    // environment — the process-wide level must be Scalar. Otherwise
    // this just asserts the detected level is executable.
    use pann::nn::gemm::{active_level, detect_with, SimdLevel};
    assert_eq!(detect_with(true), SimdLevel::Scalar);
    let env_forced =
        std::env::var_os("PANN_FORCE_SCALAR").is_some_and(|v| !v.is_empty() && v != "0");
    if cfg!(feature = "force-scalar") || env_forced {
        assert_eq!(active_level(), SimdLevel::Scalar);
    } else {
        assert_eq!(active_level().supported(), active_level());
    }
}

#[test]
fn prop_multipliers_agree_and_are_exact() {
    let mut rng = Rng::new(104);
    for _ in 0..40 {
        let b = 2 + rng.below(7) as u32;
        let hi = 1i64 << (b - 1);
        let mut booth = BoothMultiplier::new(b, true);
        let mut serial = SerialMultiplier::new(b, true);
        for _ in 0..200 {
            let w = rng.range_i64(-hi, hi);
            let x = rng.range_i64(-hi, hi);
            let (pb, _) = booth.mul(w, x);
            let (ps, _) = serial.mul(w, x);
            assert_eq!(pb, w * x);
            assert_eq!(ps, w * x);
        }
    }
}

#[test]
fn prop_toggle_counts_bounded_by_register_sizes() {
    // No instruction can toggle more bits than exist in the datapath.
    let mut rng = Rng::new(105);
    for _ in 0..20 {
        let b = 2 + rng.below(7) as u32;
        let hi = 1i64 << (b - 1);
        let mut m = BoothMultiplier::new(b, true);
        // rows+sums+carries: 3 registers × b stages × 2b bits, plus
        // inputs (2b + 2b encoder) and output 2b.
        let bound = (3 * b * 2 * b + 6 * b) as u64;
        for _ in 0..300 {
            let (_, t) = m.mul(rng.range_i64(-hi, hi), rng.range_i64(-hi, hi));
            assert!(t.total() <= bound, "b={b}: {} > {bound}", t.total());
        }
    }
}

#[test]
fn prop_power_models_monotone_in_bits() {
    use pann::power::model::*;
    for b in 2..8u32 {
        assert!(mac_power_signed(b + 1, 32).total() > mac_power_signed(b, 32).total());
        assert!(mac_power_unsigned(b + 1).total() > mac_power_unsigned(b).total());
        assert!(mult_power_mixed_signed(b + 1, 8) >= mult_power_mixed_signed(b, 8));
    }
}

#[test]
fn prop_unsigned_never_costs_more_than_signed() {
    use pann::power::model::*;
    for b in 2..=8u32 {
        for acc in [16u32, 24, 32, 48] {
            assert!(mac_power_unsigned(b).total() <= mac_power_signed(b, acc).total());
        }
    }
}

#[test]
fn prop_quantized_forward_deterministic() {
    use pann::data::{synth, Dataset};
    use pann::nn::eval::{batch_tensor, eval_quantized};
    use pann::nn::quantized::{QuantConfig, QuantizedModel};
    use pann::nn::Model;
    use pann::quant::ActQuantMethod;
    let mut model = Model::reference_cnn(31);
    let ds = Dataset::from_synth(synth::digits(24, 32));
    let x = batch_tensor(&ds, 0, 16);
    model.record_act_stats(&x).unwrap();
    let qm = QuantizedModel::prepare(
        &model,
        QuantConfig::pann(5, 2.0, ActQuantMethod::BnStats),
        None,
    )
    .unwrap();
    let a = eval_quantized(&qm, &ds).unwrap();
    let b = eval_quantized(&qm, &ds).unwrap();
    assert_eq!(a.correct, b.correct);
    assert!((a.giga_flips - b.giga_flips).abs() < 1e-15);
}

#[test]
fn prop_tensor_io_roundtrip_random() {
    use pann::data::tensor_io::{parse_tensor, write_tensor, TensorData};
    let mut rng = Rng::new(106);
    let dir = std::env::temp_dir().join("pann_prop_io");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..40 {
        let ndim = 1 + rng.below(4);
        let shape: Vec<usize> = (0..ndim).map(|_| 1 + rng.below(6)).collect();
        let n: usize = shape.iter().product();
        let t = match rng.below(3) {
            0 => TensorData::F32(shape, (0..n).map(|_| rng.normal() as f32).collect()),
            1 => TensorData::I32(shape, (0..n).map(|_| rng.range_i64(-1000, 1000) as i32).collect()),
            _ => TensorData::U8(shape, (0..n).map(|_| rng.below(256) as u8).collect()),
        };
        let p = dir.join(format!("t{case}.ptns"));
        write_tensor(&p, &t).unwrap();
        let raw = std::fs::read(&p).unwrap();
        assert_eq!(parse_tensor(&raw).unwrap(), t);
    }
}

#[test]
fn prop_pareto_menu_monotone_and_select_undominated() {
    // The menu-compiler invariants, over random candidate clouds:
    // (1) the pruned frontier is strictly monotone in both cost and
    //     accuracy;
    // (2) every dropped candidate is dominated by a kept one;
    // (3) `PowerPolicy::select` over the pruned menu always returns
    //     the most accurate affordable point — never a dominated one.
    use pann::coordinator::{Costed, PowerPolicy};
    use pann::pann::pareto_prune;

    struct Pt {
        name: String,
        cost: f64,
    }
    impl Costed for Pt {
        fn point_name(&self) -> &str {
            &self.name
        }
        fn cost_gflips(&self) -> f64 {
            self.cost
        }
    }

    let mut rng = Rng::new(107);
    for _ in 0..CASES {
        let n = 1 + rng.below(40);
        let cands: Vec<(f64, f64)> = (0..n).map(|_| (rng.f64() * 10.0, rng.f64())).collect();
        let kept = pareto_prune(cands.clone(), |c| c.0, |c| c.1);
        assert!(!kept.is_empty(), "pruning must keep at least the cheapest point");
        for w in kept.windows(2) {
            assert!(
                w[1].0 > w[0].0 && w[1].1 > w[0].1,
                "frontier not strictly monotone: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
        for c in &cands {
            if kept.contains(c) {
                continue;
            }
            assert!(
                kept.iter().any(|k| k.0 <= c.0 && k.1 >= c.1),
                "dropped candidate {c:?} is not dominated by any kept point"
            );
        }
        let policy = PowerPolicy::new(
            kept.iter()
                .enumerate()
                .map(|(i, k)| Pt { name: format!("p{i}"), cost: k.0 })
                .collect(),
        )
        .unwrap();
        for _ in 0..20 {
            let budget = rng.f64() * 12.0;
            let idx = policy.select(budget).unwrap();
            // expected: the priciest affordable point (menu accuracy is
            // monotone in cost), falling back to the cheapest
            let want = kept.iter().rposition(|k| k.0 <= budget).unwrap_or(0);
            assert_eq!(idx, want, "budget {budget}");
            // never dominated: no affordable alternative beats it
            for (j, k) in kept.iter().enumerate() {
                if k.0 <= budget && j != idx {
                    assert!(k.1 < kept[idx].1, "select picked a dominated point");
                }
            }
        }
    }
}

#[test]
fn prop_kernel_cert_acc_hull_matches_i128_brute_force() {
    use pann::analysis::{Interval, KernelCert};
    let mut rng = Rng::new(104);
    for case in 0..CASES {
        // operand magnitudes up to 2^16 and depths up to 4096, so
        // depth·act·|w| straddles the i32 boundary from both sides
        let act_hi = 1i128 << (1 + rng.below(16));
        let act_lo = if rng.below(2) == 0 { 0 } else { -act_hi };
        let w_mag = 1i128 << (1 + rng.below(16));
        let (w_lo, w_hi) = match rng.below(3) {
            0 => (-w_mag, w_mag),
            1 => (0, w_mag),
            _ => (-w_mag, 0),
        };
        let depth = 1 + rng.below(4096) as u64;
        let split = rng.below(2) == 0;
        let cert = KernelCert::certify(
            Interval::new(act_lo, act_hi),
            Interval::new(w_lo, w_hi),
            depth,
            split,
        );

        // Brute-force extrema by construction: a dot product is a sum of
        // `depth` independent per-element products, so its extrema are
        // reached by `depth` copies of the extreme corner pair. Sum those
        // copies one by one in i128 — an independent route to the hull.
        let corners = [
            (act_lo, w_lo),
            (act_lo, w_hi),
            (act_hi, w_lo),
            (act_hi, w_hi),
        ];
        let pmax = corners.iter().map(|&(a, w)| a * w).max().unwrap();
        let pmin = corners.iter().map(|&(a, w)| a * w).min().unwrap();
        let (mut smax, mut smin) = (0i128, 0i128);
        for _ in 0..depth {
            smax += pmax;
            smin += pmin;
        }
        assert_eq!((cert.acc.lo, cert.acc.hi), (smin, smax), "case {case}");

        // the verdicts are exactly the brute-force fit checks
        let ops_i32 = act_lo >= i32::MIN as i128
            && act_hi <= i32::MAX as i128
            && w_lo >= i32::MIN as i128
            && w_hi <= i32::MAX as i128;
        let sum_i32 = smin >= i32::MIN as i128 && smax <= i32::MAX as i128;
        assert_eq!(cert.i32_ok, sum_i32 && ops_i32, "case {case}");
        let ops_i16 = act_lo >= i16::MIN as i128
            && act_hi <= i16::MAX as i128
            && w_lo >= i16::MIN as i128
            && w_hi <= i16::MAX as i128;
        assert_eq!(cert.packed_i16_ok, cert.i32_ok && ops_i16, "case {case}");

        if split {
            // split banks: p = max(w, 0), n = max(−w, 0); brute-force each
            // bank's extreme partial sum the same constructive way
            let (p_lo, p_hi) = (w_lo.max(0), w_hi.max(0));
            let (n_lo, n_hi) = ((-w_hi).max(0), (-w_lo).max(0));
            for (bank, (b_lo, b_hi)) in
                [(cert.pos_acc, (p_lo, p_hi)), (cert.neg_acc, (n_lo, n_hi))]
            {
                let bc = [
                    act_lo * b_lo,
                    act_lo * b_hi,
                    act_hi * b_lo,
                    act_hi * b_hi,
                ];
                let (mut bmax, mut bmin) = (0i128, 0i128);
                for _ in 0..depth {
                    bmax += bc.iter().max().unwrap();
                    bmin += bc.iter().min().unwrap();
                }
                assert_eq!((bank.lo, bank.hi), (bmin, bmax), "case {case}");
            }
            let diff_lo = cert.pos_acc.lo - cert.neg_acc.hi;
            let diff_hi = cert.pos_acc.hi - cert.neg_acc.lo;
            let all_i64 = [
                cert.pos_acc.lo,
                cert.pos_acc.hi,
                cert.neg_acc.lo,
                cert.neg_acc.hi,
                diff_lo,
                diff_hi,
            ]
            .iter()
            .all(|&v| v >= i64::MIN as i128 && v <= i64::MAX as i128);
            assert_eq!(cert.i64_ok, all_i64, "case {case}");
        } else {
            let sum_i64 = smin >= i64::MIN as i128 && smax <= i64::MAX as i128;
            assert_eq!(cert.i64_ok, sum_i64, "case {case}");
        }
    }
}

#[test]
fn prop_admitted_narrow_wrapping_fold_equals_true_sum() {
    use pann::analysis::{Interval, KernelCert};
    let mut rng = Rng::new(105);
    let mut admitted = 0usize;
    for _ in 0..CASES {
        let depth = 1 + rng.below(512);
        let act_hi = 1 + rng.below(1 << 12) as i128;
        let w_mag = 1 + rng.below(1 << 12) as i128;
        let acts: Vec<i64> = (0..depth).map(|_| rng.range_i64(0, act_hi as i64)).collect();
        let ws: Vec<i64> = (0..depth)
            .map(|_| rng.range_i64(-(w_mag as i64), w_mag as i64))
            .collect();
        let cert = KernelCert::certify(
            Interval::new(0, act_hi),
            Interval::new(-w_mag, w_mag),
            depth as u64,
            false,
        );
        let true_sum: i128 = acts
            .iter()
            .zip(&ws)
            .map(|(&a, &w)| a as i128 * w as i128)
            .sum();
        assert!(
            cert.acc.contains(true_sum),
            "every concrete dot product lies in the certified hull"
        );
        if cert.admits_narrow() {
            admitted += 1;
            // fold in wrapping i32, exactly like the narrow kernels
            let mut acc = 0i32;
            for (&a, &w) in acts.iter().zip(&ws) {
                acc = acc.wrapping_add((a as i32).wrapping_mul(w as i32));
            }
            assert_eq!(acc as i128, true_sum, "narrow verdict must be exact");
        }
    }
    assert!(admitted > 0, "sampler never produced an admitted config");

    // and a certified-unsafe config really can wrap: the greedy extreme
    // vector overflows i32 while the wrapped fold silently disagrees
    let cert = KernelCert::certify(Interval::new(0, 1 << 10), Interval::new(0, 1 << 12), 1024, false);
    assert!(!cert.admits_narrow());
    let (a, w, depth) = (1i64 << 10, 1i64 << 12, 1024usize);
    let true_sum = (a as i128) * (w as i128) * depth as i128;
    let mut acc = 0i32;
    for _ in 0..depth {
        acc = acc.wrapping_add((a as i32).wrapping_mul(w as i32));
    }
    assert_ne!(acc as i128, true_sum, "the rejected config does overflow");
}

#[test]
fn prop_mixed_frontier_dominates_uniform() {
    // The pann-menu/v3 headline claim: because the mixed-precision
    // search prunes the *union* of uniform and mixed candidates, the
    // resulting frontier weakly dominates the uniform-only frontier —
    // for every uniform frontier point there is a merged point with
    // ≤ cost and ≥ accuracy — and the merged frontier stays strictly
    // Pareto-monotone. First over random candidate clouds (the pure
    // pruning logic), then on a real compiled model.
    use pann::pann::pareto_prune;
    let mut rng = Rng::new(108);
    for _ in 0..CASES {
        let nu = 1 + rng.below(30);
        let nm = rng.below(30);
        let uniform: Vec<(f64, f64)> = (0..nu).map(|_| (rng.f64() * 10.0, rng.f64())).collect();
        let mixed: Vec<(f64, f64)> = (0..nm).map(|_| (rng.f64() * 10.0, rng.f64())).collect();
        let uni_frontier = pareto_prune(uniform.clone(), |c| c.0, |c| c.1);
        let mut union = uniform.clone();
        union.extend(mixed.iter().copied());
        let merged = pareto_prune(union, |c| c.0, |c| c.1);
        for w in merged.windows(2) {
            assert!(
                w[1].0 > w[0].0 && w[1].1 > w[0].1,
                "merged frontier not strictly monotone: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
        for u in &uni_frontier {
            assert!(
                merged.iter().any(|m| m.0 <= u.0 && m.1 >= u.1),
                "uniform frontier point {u:?} not weakly dominated by the merged frontier"
            );
        }
    }

    // the same claim end-to-end on a real model: the per-layer search
    // merges its mixed candidates into the very same pruning
    use pann::data::{synth, Dataset};
    use pann::nn::eval::batch_tensor;
    use pann::nn::Model;
    use pann::pann::{compile_menu, compile_menu_per_layer, PerLayerSearch};
    use pann::quant::ActQuantMethod;
    let mut model = Model::reference_cnn(53);
    let ds = Dataset::from_synth(synth::digits(48, 54));
    model.record_act_stats(&batch_tensor(&ds, 0, 24)).unwrap();
    let uni = compile_menu(&model, &[2, 4], ActQuantMethod::BnStats, None, &ds, 2..=6).unwrap();
    let mixed = compile_menu_per_layer(
        &model,
        &[2, 4],
        ActQuantMethod::BnStats,
        None,
        &ds,
        2..=6,
        PerLayerSearch { sensitivity_samples: 12, max_mixed_points: 3 },
    )
    .unwrap();
    for w in mixed.points.windows(2) {
        assert!(w[1].gflips_per_sample > w[0].gflips_per_sample && w[1].val_acc > w[0].val_acc);
    }
    for u in &uni.points {
        assert!(
            mixed
                .points
                .iter()
                .any(|m| m.gflips_per_sample <= u.gflips_per_sample && m.val_acc >= u.val_acc),
            "uniform point {} not weakly dominated by the mixed frontier",
            u.name
        );
    }
}

#[test]
fn prop_trace_generator_deterministic_and_sorted() {
    // The scenario harness's foundation: every workload family, under
    // random generator knobs, must (a) regenerate byte-identically
    // from its seed, (b) emit offset-sorted events inside the trace
    // duration, (c) keep deadlines and energy caps inside the schema
    // bounds, and (d) actually depend on the seed.
    use pann::coordinator::Priority;
    use pann::scenario::trace::{MAX_DEADLINE_US, MIN_DEADLINE_US};
    use pann::scenario::{Trace, TraceFamily, TraceParams};
    let mut meta = Rng::new(907);
    for _ in 0..24 {
        let params = TraceParams {
            seed: meta.next_u64(),
            events: 1 + meta.below(300),
            duration_us: 50_000 + meta.below(3_000_000) as u64,
            tenants: 1 + meta.below(8),
        };
        for family in TraceFamily::ALL {
            let a = Trace::generate(family, &params);
            let b = Trace::generate(family, &params);
            assert_eq!(a, b, "same seed must regenerate the identical trace");
            assert_eq!(a.to_json().to_string(), b.to_json().to_string());
            assert_eq!(a.events.len(), params.events, "{family:?}");
            a.validate().unwrap();
            let mut prev = 0u64;
            for e in &a.events {
                assert!(e.offset_us >= prev, "{family:?}: offsets must be sorted");
                assert!(e.offset_us <= a.duration_us);
                prev = e.offset_us;
                if let Some(d) = e.deadline_us {
                    assert!((MIN_DEADLINE_US..=MAX_DEADLINE_US).contains(&d), "{family:?}: {d}");
                }
                if let Some(g) = e.max_gflips {
                    assert!(g.is_finite() && g > 0.0, "{family:?}: cap {g}");
                }
                assert!(Priority::ALL.contains(&e.priority));
            }
            let reseeded = TraceParams { seed: params.seed ^ 0x9e37_79b9_7f4a_7c15, ..params };
            let other = Trace::generate(family, &reseeded);
            if params.events >= 8 {
                assert_ne!(a.events, other.events, "{family:?} must depend on its seed");
            }
        }
    }
}
