//! Exhaustive-interleaving models of the coordinator's concurrency
//! protocols, gated behind `--cfg loom` (the CI loom leg sets
//! `RUSTFLAGS="--cfg loom"`; a plain `cargo test` compiles this file
//! to nothing).
//!
//! The offline registry carries no `loom` crate, so the checker is
//! hand-rolled in its spirit: each protocol is modeled as a small set
//! of per-thread state machines whose steps are the protocol's atomic
//! transitions (one critical section or one atomic access per step),
//! and [`explore`] enumerates **every** interleaving of those steps by
//! depth-first search, asserting the protocol invariants in every
//! reachable state and that no schedule deadlocks. The models mirror
//! the production structures they certify:
//!
//! - `RequestQueue` push/drain handshake (`coordinator/batcher.rs`):
//!   bounded queue, full-queue shedding, stop-flag shutdown — no
//!   request is ever lost or duplicated, and the drain loop terminates
//!   in every interleaving.
//! - The shared envelope cell (`coordinator/governor.rs`): f64 bits
//!   published through one `AtomicU64` — every read observes exactly
//!   the old or the new bits (never a torn mix), and per-variable
//!   coherence keeps reads monotone once the new value is seen.
//! - `Governor::set_envelope_rate` re-targeting vs. the observe loop:
//!   however the re-target interleaves with breach/clear decisions,
//!   the degradation level stays in range and the effective budget
//!   stays positive.

#![cfg(loom)]
// Models assert freely; the clippy.toml panic ban targets the
// production serving layer, not test crates.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

/// One modeled thread: a cloneable state machine advanced one atomic
/// protocol step at a time.
trait ModelThread<S: Clone>: Clone {
    /// Has the thread run to completion?
    fn done(&self) -> bool;
    /// Could the thread make progress right now (not blocked on the
    /// shared state)? A blocked thread is simply not scheduled; a
    /// schedule where nothing is runnable and not everything is done
    /// is a deadlock and fails the check.
    fn runnable(&self, shared: &S) -> bool;
    /// Execute one atomic step.
    fn step(&mut self, shared: &mut S);
}

/// Depth-first enumeration of every interleaving: at each state, fork
/// one branch per runnable thread. `check` runs on every *terminal*
/// state (all threads done); per-step invariants live inside `step`.
fn explore<S: Clone, T: ModelThread<S>>(shared: &S, threads: &[T], check: &mut dyn FnMut(&S)) {
    let mut forked = false;
    for i in 0..threads.len() {
        if threads[i].done() || !threads[i].runnable(shared) {
            continue;
        }
        forked = true;
        let mut s = shared.clone();
        let mut ts = threads.to_vec();
        ts[i].step(&mut s);
        explore(&s, &ts, check);
    }
    if !forked {
        assert!(
            threads.iter().all(ModelThread::done),
            "deadlock: no thread runnable but not all are done"
        );
        check(shared);
    }
}

// --- model 1: RequestQueue push/drain handshake ------------------------

/// Shared state of the batcher handshake: the bounded queue, the stop
/// flag, and the consumer's transcript.
#[derive(Clone)]
struct QueueState {
    queue: Vec<u32>,
    cap: usize,
    stopped: bool,
    producer_done: bool,
    drained: Vec<u32>,
    shed: Vec<u32>,
}

#[derive(Clone)]
enum QueueThread {
    /// Pushes ids `next..n`; a full queue sheds (QueueFull) exactly
    /// like `Batcher::push`, a stopped queue rejects the rest.
    Producer { next: u32, n: u32 },
    /// Drains batches until the producer is done and the queue is
    /// empty — the worker-loop shape of `Batcher::collect`.
    Consumer { live: bool },
    /// Flips the stop flag once (`Batcher::stop`).
    Stopper { fired: bool },
}

impl ModelThread<QueueState> for QueueThread {
    fn done(&self) -> bool {
        match self {
            QueueThread::Producer { next, n } => next >= n,
            QueueThread::Consumer { live } => !live,
            QueueThread::Stopper { fired } => *fired,
        }
    }

    fn runnable(&self, s: &QueueState) -> bool {
        match self {
            // push never blocks: full or stopped sheds immediately
            QueueThread::Producer { .. } | QueueThread::Stopper { .. } => true,
            // the consumer parks on the condvar until there is work,
            // the producer finished, or the server is stopping
            QueueThread::Consumer { .. } => {
                !s.queue.is_empty() || s.producer_done || s.stopped
            }
        }
    }

    fn step(&mut self, s: &mut QueueState) {
        match self {
            QueueThread::Producer { next, n } => {
                let id = *next;
                if s.stopped || s.queue.len() >= s.cap {
                    s.shed.push(id);
                } else {
                    s.queue.push(id);
                }
                *next += 1;
                if *next >= *n {
                    s.producer_done = true;
                }
            }
            QueueThread::Consumer { live } => {
                if !s.queue.is_empty() {
                    s.drained.append(&mut s.queue);
                } else {
                    // woke with an empty queue: exit iff shutdown
                    debug_assert!(s.producer_done || s.stopped);
                    *live = false;
                }
            }
            QueueThread::Stopper { fired } => {
                s.stopped = true;
                *fired = true;
            }
        }
    }
}

#[test]
fn queue_handshake_never_loses_or_duplicates_requests() {
    let n = 4u32;
    let shared = QueueState {
        queue: Vec::new(),
        cap: 2,
        stopped: false,
        producer_done: false,
        drained: Vec::new(),
        shed: Vec::new(),
    };
    let threads = vec![
        QueueThread::Producer { next: 0, n },
        QueueThread::Consumer { live: true },
    ];
    let mut terminal = 0usize;
    explore(&shared, &threads, &mut |s| {
        terminal += 1;
        // every request got exactly one fate: drained or shed
        let mut all: Vec<u32> = s.drained.iter().chain(&s.shed).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "lost or duplicated ids");
        // FIFO order survives batching
        assert!(s.drained.windows(2).all(|w| w[0] < w[1]));
        assert!(s.queue.is_empty(), "terminal state left requests behind");
    });
    assert!(terminal > 1, "checker explored only one schedule");
}

#[test]
fn queue_stop_always_terminates_and_sheds_the_rest() {
    let n = 3u32;
    let shared = QueueState {
        queue: Vec::new(),
        cap: 8,
        stopped: false,
        producer_done: false,
        drained: Vec::new(),
        shed: Vec::new(),
    };
    let threads = vec![
        QueueThread::Producer { next: 0, n },
        QueueThread::Consumer { live: true },
        QueueThread::Stopper { fired: false },
    ];
    explore(&shared, &threads, &mut |s| {
        // termination in every interleaving is the deadlock assert in
        // `explore`; here: no id vanished, whatever the stop timing
        assert_eq!(s.drained.len() + s.shed.len(), n as usize);
    });
}

// --- model 2: the shared envelope cell ---------------------------------

/// One `AtomicU64` publishing f64 bits (the governor's envelope-rate
/// cell). Reads and writes of the single cell are atomic steps.
#[derive(Clone)]
struct CellState {
    bits: u64,
}

#[derive(Clone)]
enum CellThread {
    /// `set_envelope_rate`: one release-store of the new bits.
    Writer { fired: bool, new: u64 },
    /// The observe loop's relaxed loads: each must see exactly the old
    /// or the new bits, and—per-variable coherence—never the old bits
    /// again after the new ones.
    Reader { reads: usize, seen_new: bool, old: u64, new: u64 },
}

impl ModelThread<CellState> for CellThread {
    fn done(&self) -> bool {
        match self {
            CellThread::Writer { fired, .. } => *fired,
            CellThread::Reader { reads, .. } => *reads == 0,
        }
    }

    fn runnable(&self, _s: &CellState) -> bool {
        true
    }

    fn step(&mut self, s: &mut CellState) {
        match self {
            CellThread::Writer { fired, new } => {
                s.bits = *new;
                *fired = true;
            }
            CellThread::Reader { reads, seen_new, old, new } => {
                let got = s.bits;
                assert!(
                    got == *old || got == *new,
                    "torn read: {got:#x} is neither the old nor the new bits"
                );
                if got == *new {
                    *seen_new = true;
                } else {
                    assert!(!*seen_new, "coherence violated: old bits after new bits");
                }
                let v = f64::from_bits(got);
                assert!(v.is_finite() && v > 0.0, "reader must always see a usable rate");
                *reads -= 1;
            }
        }
    }
}

#[test]
fn envelope_cell_reads_are_never_torn_and_stay_coherent() {
    let old = 10.0f64.to_bits();
    let new = 25.0f64.to_bits();
    let shared = CellState { bits: old };
    let threads = vec![
        CellThread::Writer { fired: false, new },
        CellThread::Reader { reads: 3, seen_new: false, old, new },
    ];
    let mut terminal = 0usize;
    explore(&shared, &threads, &mut |s| {
        terminal += 1;
        assert_eq!(s.bits, new, "the write must eventually be visible");
    });
    // 1 writer step interleaved into 3 reader steps: 4 schedules
    assert_eq!(terminal, 4);
}

// --- model 3: governor re-targeting vs. the observe loop ---------------

/// Degradation ladder the observe loop walks (most-accurate first).
const LEVELS: [f64; 3] = [1.0, 0.5, 0.25];

/// Governor state under one lock: the envelope rate, the ladder
/// position, and the published budget multiplier.
#[derive(Clone)]
struct GovState {
    rate: f64,
    level: usize,
    budget: f64,
}

#[derive(Clone)]
enum GovThread {
    /// The observe loop: each step is one locked decision window
    /// comparing a fixed measured rate against the envelope and moving
    /// one rung (the `Governor::observe` shape).
    Observer { windows: usize, measured: f64 },
    /// `set_envelope_rate`: re-target the envelope mid-run.
    Retarget { fired: bool, new_rate: f64 },
}

impl ModelThread<GovState> for GovThread {
    fn done(&self) -> bool {
        match self {
            GovThread::Observer { windows, .. } => *windows == 0,
            GovThread::Retarget { fired, .. } => *fired,
        }
    }

    fn runnable(&self, _s: &GovState) -> bool {
        true
    }

    fn step(&mut self, s: &mut GovState) {
        match self {
            GovThread::Observer { windows, measured } => {
                if *measured > s.rate {
                    s.level = (s.level + 1).min(LEVELS.len() - 1);
                } else {
                    s.level = s.level.saturating_sub(1);
                }
                s.budget = LEVELS[s.level];
                *windows -= 1;
            }
            GovThread::Retarget { fired, new_rate } => {
                s.rate = *new_rate;
                *fired = true;
            }
        }
    }
}

#[test]
fn retargeting_mid_run_keeps_the_budget_positive_and_the_level_in_range() {
    // measured load of 20 Gflips/s: a breach under the initial 10
    // envelope, clear under the re-targeted 40 — every interleaving of
    // the re-target among the windows must stay inside the ladder
    let shared = GovState { rate: 10.0, level: 0, budget: LEVELS[0] };
    let threads = vec![
        GovThread::Observer { windows: 4, measured: 20.0 },
        GovThread::Retarget { fired: false, new_rate: 40.0 },
    ];
    let mut terminal = 0usize;
    explore(&shared, &threads, &mut |s| {
        terminal += 1;
        assert!(s.level < LEVELS.len());
        assert!(s.budget > 0.0 && s.budget <= 1.0);
        assert_eq!(s.rate, 40.0);
    });
    // 1 re-target step into 4 windows: 5 schedules
    assert_eq!(terminal, 5);
}
