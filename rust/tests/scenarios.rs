//! Scenario matrix: deterministic fleet behavior under replayed
//! workloads.
//!
//! Every test here runs the virtual-clock replay rig
//! (`pann::scenario`) — no sleeps, no wall-clock assertions, and the
//! same seed always replays the same trace, so each expectation below
//! is a fixed fact about the code, not a race. The one exception is
//! the final test, which feeds trace events through a *live*
//! [`ShardRouter`] to pin the bridge between the replayable format
//! and the real serving stack (its assertions are count identities,
//! not timings).

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::coordinator::Priority;
use pann::net::rendezvous_order;
use pann::scenario::{
    replay, DeviceProfile, FrontierPoint, OutcomeCounts, ReplayConfig, ScenarioReport, Trace,
    TraceEvent, TraceFamily, TraceParams,
};
use std::collections::BTreeMap;

/// Synthetic three-point frontier (costs in Gflips/sample).
fn frontier3() -> Vec<FrontierPoint> {
    vec![
        FrontierPoint { name: "cheap".into(), cost_gflips: 0.02, acc_proxy: 0.90 },
        FrontierPoint { name: "mid".into(), cost_gflips: 0.08, acc_proxy: 0.95 },
        FrontierPoint { name: "rich".into(), cost_gflips: 0.32, acc_proxy: 0.985 },
    ]
}

fn by_priority(report: &ScenarioReport) -> BTreeMap<&str, OutcomeCounts> {
    report.per_priority.iter().map(|(n, c)| (n.as_str(), *c)).collect()
}

#[test]
fn flash_crowd_degrades_along_the_frontier_then_recovers() {
    // A flash crowd on a 5 GF/s envelope: the burst saturates the
    // shard, so while it lasts the governor observes energy at the
    // device drain rate (250 GF/s on `server`) — orders of magnitude
    // over target — and must walk down the frontier. Once the trace
    // drains, the trailing idle windows must climb all the way back.
    let trace = Trace::generate(TraceFamily::FlashCrowd, &TraceParams::default());
    let mut cfg = ReplayConfig::new(DeviceProfile::server());
    cfg.envelope_gflips_per_sec = Some(5.0);
    let report = replay(&trace, &frontier3(), &cfg).unwrap();
    assert!(report.invariants().is_empty(), "{:?}", report.invariants());
    let g = &report.governors[0];
    assert!(g.switches >= 2, "burst must force at least one round trip: {g:?}");
    assert_eq!(g.point, "rich", "idle tail must recover the top point: {g:?}");
    let degraded: u64 = g
        .residency
        .iter()
        .filter(|(name, _)| name != "rich")
        .map(|(_, w)| w)
        .sum();
    assert!(degraded > 0, "some windows must run degraded: {:?}", g.residency);
}

#[test]
fn skewed_tenants_never_starve_the_cold_one() {
    // 85% of traffic hammers tenant-0; the cold tenants live on
    // whatever shard the rendezvous rule gives them. A cold tenant
    // placed on a different shard than the hot one must be served in
    // full — per-shard queues and per-shard governors isolate it.
    let params = TraceParams { seed: 7, events: 512, duration_us: 2_000_000, tenants: 4 };
    let trace = Trace::generate(TraceFamily::TenantSkew, &params);
    let mut cfg = ReplayConfig::new(DeviceProfile::server());
    cfg.shards = 2;
    let report = replay(&trace, &frontier3(), &cfg).unwrap();
    assert!(report.invariants().is_empty(), "{:?}", report.invariants());

    let hot_primary = rendezvous_order("tenant-0", 2)[0];
    let cold = (1..params.tenants)
        .map(|i| format!("tenant-{i}"))
        .find(|key| rendezvous_order(key, 2)[0] != hot_primary)
        .expect("with 4 tenants on 2 shards some tenant must land off the hot shard");
    let hot = &report.per_tenant["tenant-0"];
    let cold_counts = &report.per_tenant[&cold];
    assert!(hot.arrivals > 5 * cold_counts.arrivals, "skew: {hot:?} vs {cold_counts:?}");
    assert!(cold_counts.arrivals > 0, "cold tenant {cold} must appear in the trace");
    assert_eq!(
        cold_counts.served, cold_counts.arrivals,
        "cold tenant {cold} must be served in full: {cold_counts:?}"
    );
}

#[test]
fn deadline_mix_sheds_best_effort_before_normal_before_hi() {
    // Adversarial hand-built mix on a single slow point (1 GF ⇒ 40 ms
    // on jetson), queue depth 2. Arrival order: a BestEffort takes the
    // device, then BestEffort, Normal fill the queue. The arriving Hi
    // must displace the queued BestEffort (newest lowest class), and
    // the following Normal — with nothing below it queued — is shed
    // itself. Hi is never shed.
    let mk = |offset_us: u64, priority: Priority| TraceEvent {
        offset_us,
        model: None,
        deadline_us: None,
        max_gflips: None,
        priority,
        affinity: None,
    };
    let trace = Trace {
        name: "adversarial-mix".into(),
        family: TraceFamily::DeadlineMix,
        seed: 0,
        duration_us: 100_000,
        events: vec![
            mk(0, Priority::BestEffort),  // served immediately (device idle)
            mk(1, Priority::BestEffort),  // queued, then evicted by Hi
            mk(2, Priority::Normal),      // queued, served after Hi
            mk(3, Priority::Hi),          // evicts the queued BestEffort
            mk(4, Priority::Normal),      // queue full, nothing below: shed
        ],
    };
    let slow = vec![FrontierPoint { name: "only".into(), cost_gflips: 1.0, acc_proxy: 0.9 }];
    let mut cfg = ReplayConfig::new(DeviceProfile::jetson());
    cfg.queue_depth = Some(2);
    let report = replay(&trace, &slow, &cfg).unwrap();
    assert!(report.invariants().is_empty(), "{:?}", report.invariants());
    let p = by_priority(&report);
    assert_eq!(p["hi"].shed, 0, "hi must never shed: {report:?}");
    assert_eq!(p["hi"].served, 1);
    assert_eq!(p["best-effort"].shed, 1, "queued best-effort must be displaced first");
    assert_eq!(p["normal"].shed, 1, "normal sheds only once nothing cheaper is queued");
    assert_eq!(report.totals.served, 3);
}

#[test]
fn generated_deadline_mix_stays_sound_under_guaranteed_overload() {
    // The generated family under a pinned top point (huge envelope,
    // so the governor never steps down): 512 arrivals in 2 s against
    // 12.8 ms services is a ~3x overload, so a large fraction *must*
    // shed or expire — and the accounting identities must survive the
    // carnage.
    let params = TraceParams { seed: 21, events: 512, duration_us: 2_000_000, tenants: 4 };
    let trace = Trace::generate(TraceFamily::DeadlineMix, &params);
    let mut cfg = ReplayConfig::new(DeviceProfile::jetson());
    cfg.envelope_gflips_per_sec = Some(1e9); // never breach: stay at `rich`
    let report = replay(&trace, &frontier3(), &cfg).unwrap();
    assert!(report.invariants().is_empty(), "{:?}", report.invariants());
    let p = by_priority(&report);
    for class in ["hi", "normal", "best-effort"] {
        assert!(p[class].arrivals > 0, "family must generate {class} events");
    }
    // capacity over the whole trace (plus queue drain) is far below
    // the arrival count, so pressure outcomes are certain
    assert!(
        report.totals.shed + report.totals.expired > 100,
        "overload must shed/expire: {:?}",
        report.totals
    );
    assert!(report.totals.served < report.totals.arrivals);
    // the governor was pinned: exactly one point ever serves
    assert_eq!(report.governors[0].switches, 0, "{:?}", report.governors[0]);
}

#[test]
fn diurnal_peaks_degrade_and_troughs_climb_back() {
    // Two diurnal cycles on the stock 40 GF/s server envelope: peak
    // buckets run ~470 arrivals/s (150 GF/s of `rich` demand — a
    // breach), troughs run ~40/s (12 GF/s — fits). The governor must
    // leave the top point during peaks and return during troughs, so
    // residency spreads over at least two points and switches happen.
    let params = TraceParams { seed: 7, events: 512, duration_us: 2_000_000, tenants: 4 };
    let trace = Trace::generate(TraceFamily::Diurnal, &params);
    let cfg = ReplayConfig::new(DeviceProfile::server());
    let report = replay(&trace, &frontier3(), &cfg).unwrap();
    assert!(report.invariants().is_empty(), "{:?}", report.invariants());
    let g = &report.governors[0];
    assert!(g.switches >= 2, "peaks and troughs must move the governor: {g:?}");
    let occupied = g.residency.iter().filter(|(_, w)| *w > 0).count();
    assert!(occupied >= 2, "residency must spread across the frontier: {:?}", g.residency);
    assert_eq!(g.point, "rich", "final idle flush must recover the top point");
}

#[test]
fn identical_replays_are_byte_identical() {
    // The harness's core promise: per-window shed/expired counts,
    // governor residency and switch counts — the whole report — is a
    // pure function of (trace, config).
    for family in TraceFamily::ALL {
        let trace = Trace::generate(family, &TraceParams::default());
        let mut cfg = ReplayConfig::new(DeviceProfile::jetson());
        cfg.shards = 2;
        let a = replay(&trace, &frontier3(), &cfg).unwrap().to_json().to_string();
        let b = replay(&trace, &frontier3(), &cfg).unwrap().to_json().to_string();
        assert_eq!(a, b, "replay must be deterministic for {family:?}");
    }
}

#[test]
fn flash_crowd_replays_mixed_menu_on_both_devices() {
    // The pann-menu/v3 serving claim, end to end: compile a uniform
    // and a per-layer mixed menu for the same model, lift both into
    // device frontiers, and replay the same flash-crowd trace through
    // each — with zero changes to the replay rig or report schema.
    use pann::data::{synth, Dataset};
    use pann::nn::eval::batch_tensor;
    use pann::nn::Model;
    use pann::pann::{compile_menu, compile_menu_per_layer, PerLayerSearch};
    use pann::quant::ActQuantMethod;
    use pann::scenario::frontier_from_menu;

    let mut model = Model::reference_cnn(61);
    let ds = Dataset::from_synth(synth::digits(48, 62));
    model.record_act_stats(&batch_tensor(&ds, 0, 24)).unwrap();
    let uni = compile_menu(&model, &[2, 4], ActQuantMethod::BnStats, None, &ds, 2..=6).unwrap();
    let mixed = compile_menu_per_layer(
        &model,
        &[2, 4],
        ActQuantMethod::BnStats,
        None,
        &ds,
        2..=6,
        PerLayerSearch { sensitivity_samples: 12, max_mixed_points: 3 },
    )
    .unwrap();
    assert!(uni.points.len() >= 2, "uniform frontier too small to degrade over");

    let trace = Trace::generate(TraceFamily::FlashCrowd, &TraceParams::default());
    for (device, envelope) in [(DeviceProfile::jetson(), 1.0), (DeviceProfile::server(), 5.0)] {
        let fu = frontier_from_menu(&uni, &device);
        let fm = frontier_from_menu(&mixed, &device);
        // selection-level accuracy: wherever the uniform frontier is
        // affordable at all, the mixed frontier's pick classifies at
        // least as well (weak domination + monotonicity make this a
        // theorem, so it holds at every device scaling)
        let pick = |f: &[FrontierPoint], b: f64| {
            f.iter().rev().find(|p| p.cost_gflips <= b).unwrap_or(&f[0]).acc_proxy
        };
        for u in &fu {
            for budget in [u.cost_gflips, u.cost_gflips * 1.5] {
                assert!(
                    pick(&fm, budget) >= pick(&fu, budget),
                    "mixed selection must not classify worse at budget {budget}"
                );
            }
        }

        let mut cfg = ReplayConfig::new(device);
        cfg.envelope_gflips_per_sec = Some(envelope);
        let rm = replay(&trace, &fm, &cfg).unwrap();
        let ru = replay(&trace, &fu, &cfg).unwrap();
        assert!(rm.invariants().is_empty(), "{:?}", rm.invariants());
        assert!(ru.invariants().is_empty(), "{:?}", ru.invariants());
        // byte-determinism holds for the mixed menu exactly as for the
        // uniform one
        let again = replay(&trace, &fm, &cfg).unwrap();
        assert_eq!(
            rm.to_json().to_string(),
            again.to_json().to_string(),
            "mixed-menu replay must be byte-deterministic on {}",
            rm.device
        );
        // the burst must force degradation and the idle tail must
        // recover the top point — the mixed ladder gives the governor
        // at least as many real rungs as the uniform one
        let distinct = |r: &ScenarioReport| {
            r.governors[0].residency.iter().filter(|(_, w)| *w > 0).count()
        };
        assert!(distinct(&rm) >= 2, "mixed replay never degraded: {:?}", rm.governors[0]);
        assert!(
            distinct(&rm) >= distinct(&ru),
            "mixed residency {:?} must cover at least the uniform spread {:?}",
            rm.governors[0].residency,
            ru.governors[0].residency
        );
        // accuracy proxy: the mixed replay loses no more accuracy than
        // the uniform replay (selection dominance is exact — asserted
        // above; the small slack absorbs budget-trajectory divergence
        // between the two governor walks)
        assert!(
            rm.mean_acc_proxy >= ru.mean_acc_proxy - 0.05,
            "mixed replay acc proxy {} fell below uniform {}",
            rm.mean_acc_proxy,
            ru.mean_acc_proxy
        );
    }
}

#[test]
fn trace_events_drive_a_live_shard_router() {
    // Bridge test: the same `TraceEvent`s replayed above convert via
    // `to_request` into real requests against a live two-shard router,
    // and the router's keyed placement must match the rendezvous rule
    // the replay rig uses. Assertions are count identities (queues are
    // deep enough that nothing sheds), not timings.
    use pann::coordinator::{BatchEngine, Menu, ServeError, ServerBuilder, SharedPoint};
    use pann::net::ShardRouter;
    use pann::nn::Scratch;
    use std::sync::Arc;

    struct FixedEngine;
    impl BatchEngine for FixedEngine {
        fn max_batch(&self) -> usize {
            8
        }
        fn sample_len(&self) -> usize {
            3
        }
        fn infer_batch(
            &self,
            _x: &[f32],
            n: usize,
            _scratch: &mut Scratch,
        ) -> anyhow::Result<Vec<f32>> {
            Ok(vec![0.0; n * 2])
        }
    }
    let menu = || {
        Menu::shared(vec![SharedPoint {
            measured_gflips_per_sample: None,
            name: "only".into(),
            giga_flips_per_sample: 0.001,
            engine: Arc::new(FixedEngine),
        }])
    };
    let router = ShardRouter::builder()
        .build(2, |_i, _env| ServerBuilder::new().workers(1).serve(menu()))
        .unwrap();

    let params = TraceParams { seed: 7, events: 64, duration_us: 500_000, tenants: 4 };
    let trace = Trace::generate(TraceFamily::TenantSkew, &params);
    let mut expected = [0u64; 2];
    let (mut served, mut expired) = (0u64, 0u64);
    for ev in &trace.events {
        let key = ev.affinity.as_deref().expect("tenant-skew events all carry a key");
        expected[rendezvous_order(key, 2)[0]] += 1;
        // no pacing: the engine is instant and queues are deep, so
        // every request is admitted on its primary shard
        match router.submit(ev.to_request(vec![0.0; 3])).unwrap().wait() {
            Ok(resp) => {
                assert_eq!(resp.point, "only");
                served += 1;
            }
            // a stalled CI box can blow a trace deadline; that is the
            // request's documented outcome, not a placement failure
            Err(ServeError::DeadlineExceeded) => expired += 1,
            Err(e) => panic!("unexpected serve error: {e}"),
        }
    }
    assert_eq!(served + expired, trace.events.len() as u64);
    assert!(served > 0, "a live router must serve most of a light trace");
    let snap = router.snapshot();
    let admitted: Vec<u64> = snap.shards.iter().map(|s| s.requests).collect();
    assert_eq!(
        admitted,
        expected.to_vec(),
        "live keyed placement must match the replay rig's rendezvous rule"
    );
    router.shutdown();
}
