//! Network-edge benchmark: a compiled menu served over loopback
//! HTTP/1.1 by a 2-shard router under an energy envelope, driven by
//! concurrent keep-alive clients (half keyless round-robin, half
//! affinity-pinned), measuring exactly the edge claims: request
//! throughput, loopback latency percentiles, shed/retry counts and the
//! per-shard envelope split.
//!
//! Emits `BENCH_net.json` (schema `bench-net/v1`): rps + p50/p99
//! loopback latency, shed totals and rate, then one record per shard
//! with admitted/shed/retried counts and the shard's envelope share.

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::coordinator::{Menu, ServerBuilder};
use pann::data::{synth, Dataset};
use pann::net::{NetConfig, NetServer, ShardRouter};
use pann::nn::eval::batch_tensor;
use pann::nn::Model;
use pann::pann::compile_menu;
use pann::quant::ActQuantMethod;
use pann::util::bench::write_json;
use pann::util::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

const SHARDS: usize = 2;
const CLIENTS: usize = 4;
const REQUESTS_PER_CLIENT: usize = 64;

fn compiled_menu(seed: u64) -> (Model, Dataset, pann::pann::MenuArtifact) {
    let mut model = Model::reference_cnn(seed);
    let ds = Dataset::from_synth(synth::digits(192, seed + 1));
    let stats = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats).expect("record stats");
    let menu = compile_menu(&model, &[2, 8], ActQuantMethod::BnStats, None, &ds.take(48), 2..=8)
        .expect("compile menu");
    (model, ds, menu)
}

/// Read one HTTP response off a keep-alive stream; returns the body.
fn read_response(r: &mut BufReader<&TcpStream>) -> String {
    let mut line = String::new();
    r.read_line(&mut line).expect("status line");
    assert!(line.starts_with("HTTP/1.1 200"), "non-200 response: {line}");
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).expect("header line");
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            len = v.trim().parse().expect("content-length");
        }
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).expect("body");
    String::from_utf8(body).expect("utf8 body")
}

/// One client: `n` sequential infer requests on one keep-alive
/// connection; returns per-request latency in microseconds.
fn drive(addr: SocketAddr, ds: &Dataset, n: usize, affinity: Option<&str>) -> Vec<f64> {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(&stream);
    let mut writer = &stream;
    let mut lat = Vec::with_capacity(n);
    for i in 0..n {
        let nums: Vec<String> =
            ds.sample(i % ds.len()).iter().map(|x| format!("{x}")).collect();
        let aff = affinity
            .map(|k| format!(r#", "affinity": "{k}""#))
            .unwrap_or_default();
        let body = format!(r#"{{"input": [{}]{aff}}}"#, nums.join(","));
        let raw = format!(
            "POST /v1/infer HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let t0 = Instant::now();
        writer.write_all(raw.as_bytes()).expect("write request");
        let resp = read_response(&mut reader);
        lat.push(t0.elapsed().as_secs_f64() * 1e6);
        assert!(resp.contains("\"point\""), "unexpected body: {resp}");
    }
    lat
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// Pull one numeric metric series (`name{shard="i"} v`) off /metrics.
fn metric(metrics: &str, name: &str, shard: usize) -> f64 {
    let needle = format!("{name}{{shard=\"{shard}\"}}");
    metrics
        .lines()
        .find(|l| l.starts_with(&needle))
        .and_then(|l| l.split_whitespace().last())
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0)
}

fn main() {
    let (model, ds, artifact) = compiled_menu(7);
    let top_cost = artifact
        .points
        .iter()
        .map(|p| p.gflips_per_sample)
        .filter(|g| g.is_finite())
        .fold(0.0f64, f64::max)
        .max(1e-12);
    println!(
        "menu: {} frontier points, top cost {top_cost:.6} GF/sample; {SHARDS} shards, \
         {CLIENTS} clients x {REQUESTS_PER_CLIENT} requests",
        artifact.points.len()
    );
    // envelope sized to keep the load comfortably servable at the top
    // point: the interesting quantities here are latency and the split,
    // not governor stepping (benches/governor.rs covers that)
    let envelope_rate = top_cost * 2000.0;
    let window = Duration::from_millis(20);
    let router = ShardRouter::builder()
        .envelope(
            pann::coordinator::EnergyEnvelope::gflips_per_sec(envelope_rate),
            top_cost,
        )
        .window(window)
        .build(SHARDS, |_, slice| {
            let mut b = ServerBuilder::new().workers(2).max_batch(8).queue_depth(256);
            if let Some(e) = slice {
                b = b.envelope(e).governor_window(window);
            }
            b.serve(Menu::shared(artifact.shared_points(&model, None, 8)?))
        })
        .expect("build router");
    let srv = NetServer::bind(
        "127.0.0.1:0",
        router,
        NetConfig { handler_threads: CLIENTS, ..NetConfig::default() },
    )
    .expect("bind edge");
    let addr = srv.local_addr();
    println!("edge on {addr}");

    let t0 = Instant::now();
    let mut lats: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let ds = &ds;
                s.spawn(move || {
                    // half the clients pin an affinity key (sticky
                    // placement), half spread round-robin
                    let key = format!("client-{c}");
                    let aff = if c % 2 == 0 { None } else { Some(key.as_str()) };
                    drive(addr, ds, REQUESTS_PER_CLIENT, aff)
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
    });
    let secs = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.total_cmp(b));
    let total = CLIENTS * REQUESTS_PER_CLIENT;
    let rps = total as f64 / secs.max(1e-9);
    let (p50, p99) = (percentile(&lats, 0.50), percentile(&lats, 0.99));
    println!("{total} requests in {secs:.2}s = {rps:.0} req/s; p50 {p50:.0} µs, p99 {p99:.0} µs");

    // pull the shard counters off the edge itself
    let stream = TcpStream::connect(addr).expect("metrics connect");
    let mut w = &stream;
    w.write_all(b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n").expect("metrics req");
    let mut metrics = String::new();
    let mut r = BufReader::new(&stream);
    r.read_to_string(&mut metrics).expect("metrics body");

    let mut shed_total = 0.0;
    let mut retries_total = 0.0;
    let mut per_shard = Vec::new();
    for i in 0..SHARDS {
        let requests = metric(&metrics, "pann_shard_requests_total", i);
        let shed = metric(&metrics, "pann_shard_shed_total", i);
        let retries = metric(&metrics, "pann_shard_retries_total", i);
        let share = metric(&metrics, "pann_shard_envelope_share_gflips_per_sec", i);
        shed_total += shed;
        retries_total += retries;
        println!(
            "shard {i}: {requests:.0} admitted, {shed:.0} shed, {retries:.0} retried-in, \
             share {share:.4} GF/s"
        );
        per_shard.push(Json::obj(vec![
            ("shard", Json::from(i)),
            ("requests", Json::Num(requests)),
            ("shed", Json::Num(shed)),
            ("retries", Json::Num(retries)),
            ("envelope_share_gflips_per_sec", Json::Num(share)),
        ]));
    }
    let doc = Json::obj(vec![
        ("schema", Json::from("bench-net/v1")),
        (
            "provenance",
            Json::from(
                "committed baseline captured on an 8-core x86-64 AVX2 dev box (cargo bench \
                 --bench net, release profile, loopback); regenerate locally to compare — \
                 absolute rps/latency numbers are machine-dependent, the shed/retry counters \
                 and the share-sum invariant are the tracked quantities",
            ),
        ),
        ("shards", Json::from(SHARDS)),
        ("clients", Json::from(CLIENTS)),
        ("requests", Json::from(total)),
        ("secs", Json::Num(secs)),
        ("rps", Json::Num(rps)),
        ("p50_us", Json::Num(p50)),
        ("p99_us", Json::Num(p99)),
        ("shed_total", Json::Num(shed_total)),
        ("shed_rate", Json::Num(shed_total / (total as f64 + shed_total).max(1.0))),
        ("retries_total", Json::Num(retries_total)),
        ("envelope_gflips_per_sec", Json::Num(envelope_rate)),
        ("per_shard", Json::Arr(per_shard)),
    ]);
    write_json("BENCH_net.json", &doc).expect("write BENCH_net.json");
    println!("wrote BENCH_net.json");
    srv.shutdown();
}
