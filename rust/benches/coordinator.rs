//! Serving-loop benchmark: batching throughput and latency percentiles
//! over the native integer engine — single worker vs worker pool,
//! with clients split across the three QoS priority classes.
//!
//! Emits `BENCH_coordinator.json` (throughput + p50/p99 per priority
//! class for each serving mode) so later PRs can track the serving
//! perf trajectory without parsing stdout — the serving counterpart
//! of `BENCH_engine.json`.

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::coordinator::{
    Client, EnginePoint, InferRequest, Menu, MetricsSnapshot, NativeEngine, PlanEngine, Priority,
    ServerBuilder, SharedPoint,
};
use pann::data::{synth, Dataset};
use pann::nn::eval::batch_tensor;
use pann::nn::quantized::{QuantConfig, QuantizedModel};
use pann::nn::Model;
use pann::quant::ActQuantMethod;
use pann::util::bench::write_json;
use pann::util::Json;
use std::sync::Arc;
use std::time::Duration;

const MAX_BATCH: usize = 16;

fn prepared_models() -> anyhow::Result<Vec<(u32, QuantizedModel)>> {
    let mut model = Model::reference_cnn(1);
    let ds = Dataset::from_synth(synth::digits(64, 2));
    let stats_x = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats_x)?;
    let mut out = Vec::new();
    for (bits, bx, r) in [(2u32, 6u32, 10.0 / 6.0 - 0.5), (4, 7, 24.0 / 7.0 - 0.5), (8, 8, 7.5)] {
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::pann(bx, r, ActQuantMethod::BnStats),
            None,
        )?;
        out.push((bits, qm));
    }
    Ok(out)
}

fn gf_per_sample(bits: u32, qm: &QuantizedModel) -> f64 {
    pann::power::model::mac_power_unsigned_total(bits) * qm.macs_per_sample as f64 / 1e9
}

/// Drive `clients` concurrent clients, one priority class per client
/// round-robin (Hi / Normal / BestEffort). Returns req/s.
fn drive(c: &Client, ds: &Dataset, label: &str, budget: f64, clients: usize) -> f64 {
    c.set_budget(budget);
    let t0 = std::time::Instant::now();
    let n_per = 64usize;
    std::thread::scope(|s| {
        for cl in 0..clients {
            let c = c.clone();
            let prio = Priority::ALL[cl % Priority::ALL.len()];
            s.spawn(move || {
                for i in 0..n_per {
                    let idx = (cl * n_per + i) % ds.len();
                    c.submit(InferRequest::new(ds.sample(idx).to_vec()).priority(prio))
                        .expect("submit")
                        .wait()
                        .expect("infer");
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let total = clients * n_per;
    let rps = total as f64 / dt;
    println!("{label:<34} {total} reqs in {dt:.3}s = {rps:.0} req/s");
    rps
}

/// One serving mode's JSON record: throughput + overall and
/// per-priority latency percentiles.
fn mode_json(rps: f64, m: &MetricsSnapshot) -> Json {
    let per_priority = m
        .per_priority
        .iter()
        .map(|pl| {
            (
                pl.priority.name().to_string(),
                Json::obj(vec![
                    ("requests", Json::Num(pl.requests as f64)),
                    ("p50_us", Json::Num(pl.p50_us)),
                    ("p99_us", Json::Num(pl.p99_us)),
                ]),
            )
        })
        .collect();
    Json::obj(vec![
        ("throughput_rps", Json::Num(rps)),
        ("requests", Json::Num(m.requests as f64)),
        ("mean_batch", Json::Num(m.mean_batch)),
        ("p50_us", Json::Num(m.p50_us)),
        ("p99_us", Json::Num(m.p99_us)),
        ("per_priority", Json::Obj(per_priority)),
    ])
}

fn main() {
    let ds = Dataset::from_synth(synth::digits(256, 5));
    let mk_builder = || {
        ServerBuilder::new()
            .max_batch(MAX_BATCH)
            .max_wait(Duration::from_micros(500))
            .queue_depth(4096)
    };

    // --- single worker, local menu (the `!Send`-engine path) ---
    let srv = mk_builder()
        .serve(Menu::local(|| {
            Ok(prepared_models()?
                .into_iter()
                .map(|(bits, qm)| EnginePoint {
                    name: format!("pann-p{bits}"),
                    giga_flips_per_sample: gf_per_sample(bits, &qm),
                    engine: Box::new(NativeEngine::new(&qm, MAX_BATCH)),
                })
                .collect())
        }))
        .expect("server start");
    let c = srv.client();
    let mut single_rps = 0.0;
    for (label, budget, clients) in [
        ("1 worker, rich budget, 4 clients", f64::INFINITY, 4usize),
        ("1 worker, 2-bit budget, 4 clients", 0.001, 4),
        ("1 worker, rich budget, 16 clients", f64::INFINITY, 16),
    ] {
        single_rps = drive(&c, &ds, label, budget, clients);
    }
    let single_metrics = c.metrics();
    println!("{}", single_metrics.report());
    srv.shutdown();

    // --- worker pool over shared execution plans ---
    let n_workers = pann::nn::eval::n_threads();
    let points: Vec<SharedPoint> = prepared_models()
        .expect("prepare")
        .into_iter()
        .map(|(bits, qm)| SharedPoint {
            measured_gflips_per_sample: None,
            name: format!("pann-p{bits}"),
            giga_flips_per_sample: gf_per_sample(bits, &qm),
            engine: Arc::new(PlanEngine::new(qm.plan(), MAX_BATCH)),
        })
        .collect();
    let srv = mk_builder()
        .workers(n_workers)
        .serve(Menu::shared(points))
        .expect("pool start");
    let c = srv.client();
    let mut pool_rps = 0.0;
    for (label, budget, clients) in [
        ("pool, rich budget, 4 clients", f64::INFINITY, 4usize),
        ("pool, 2-bit budget, 4 clients", 0.001, 4),
        ("pool, rich budget, 16 clients", f64::INFINITY, 16),
    ] {
        pool_rps = drive(&c, &ds, &format!("{label} ({n_workers}w)"), budget, clients);
    }
    let pool_metrics = c.metrics();
    println!("{}", pool_metrics.report());
    srv.shutdown();

    // machine-readable perf trajectory (throughput from the final
    // 16-client drive of each mode; percentiles over the whole run)
    let doc = Json::obj(vec![
        ("schema", Json::from("bench-coordinator/v1")),
        ("workers", Json::from(n_workers)),
        ("max_batch", Json::from(MAX_BATCH)),
        ("single", mode_json(single_rps, &single_metrics)),
        ("pool", mode_json(pool_rps, &pool_metrics)),
    ]);
    write_json("BENCH_coordinator.json", &doc).expect("write BENCH_coordinator.json");
    println!("wrote BENCH_coordinator.json");
}
