//! Serving-loop benchmark: batching throughput and latency percentiles
//! over the native integer engine — single worker vs worker pool.

use pann::coordinator::server::NativeEngine;
use pann::coordinator::{EnginePoint, PlanEngine, Server, ServerConfig, SharedPoint};
use pann::data::{synth, Dataset};
use pann::nn::eval::batch_tensor;
use pann::nn::quantized::{QuantConfig, QuantizedModel};
use pann::nn::Model;
use pann::quant::ActQuantMethod;
use std::sync::Arc;
use std::time::Duration;

fn prepared_models() -> anyhow::Result<Vec<(u32, QuantizedModel)>> {
    let mut model = Model::reference_cnn(1);
    let ds = Dataset::from_synth(synth::digits(64, 2));
    let stats_x = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats_x)?;
    let mut out = Vec::new();
    for (bits, bx, r) in [(2u32, 6u32, 10.0 / 6.0 - 0.5), (4, 7, 24.0 / 7.0 - 0.5), (8, 8, 7.5)] {
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::pann(bx, r, ActQuantMethod::BnStats),
            None,
        )?;
        out.push((bits, qm));
    }
    Ok(out)
}

fn gf_per_sample(bits: u32, qm: &QuantizedModel) -> f64 {
    pann::power::model::mac_power_unsigned_total(bits) * qm.macs_per_sample as f64 / 1e9
}

fn drive(h: &pann::coordinator::ServerHandle, ds: &Dataset, label: &str, budget: f64, clients: usize) {
    h.set_budget(budget);
    let t0 = std::time::Instant::now();
    let n_per = 64usize;
    std::thread::scope(|s| {
        for c in 0..clients {
            let h = h.clone();
            s.spawn(move || {
                for i in 0..n_per {
                    let idx = (c * n_per + i) % ds.len();
                    h.infer(ds.sample(idx).to_vec()).expect("infer");
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let total = clients * n_per;
    println!(
        "{label:<34} {total} reqs in {dt:.3}s = {:.0} req/s",
        total as f64 / dt
    );
}

fn main() {
    let cfg = ServerConfig {
        max_batch: 16,
        max_wait: Duration::from_micros(500),
        budget_gflips: f64::INFINITY,
    };
    let ds = Dataset::from_synth(synth::digits(256, 5));

    // --- single worker (the seed architecture) ---
    let srv = Server::start(
        || {
            Ok(prepared_models()?
                .into_iter()
                .map(|(bits, qm)| EnginePoint {
                    name: format!("pann-p{bits}"),
                    giga_flips_per_sample: gf_per_sample(bits, &qm),
                    engine: Box::new(NativeEngine::new(&qm, vec![1, 16, 16])),
                })
                .collect())
        },
        256,
        cfg,
    )
    .expect("server start");
    let h = srv.handle();
    for (label, budget, clients) in [
        ("1 worker, rich budget, 4 clients", f64::INFINITY, 4usize),
        ("1 worker, 2-bit budget, 4 clients", 0.001, 4),
        ("1 worker, rich budget, 16 clients", f64::INFINITY, 16),
    ] {
        drive(&h, &ds, label, budget, clients);
    }
    println!("{}", h.metrics().report());
    srv.shutdown();

    // --- worker pool over shared execution plans ---
    let n_workers = pann::nn::eval::n_threads();
    let points: Vec<SharedPoint> = prepared_models()
        .expect("prepare")
        .into_iter()
        .map(|(bits, qm)| SharedPoint {
            name: format!("pann-p{bits}"),
            giga_flips_per_sample: gf_per_sample(bits, &qm),
            engine: Arc::new(PlanEngine::new(qm.plan(), vec![1, 16, 16])),
        })
        .collect();
    let srv = Server::start_pool(points, 256, cfg, n_workers).expect("pool start");
    let h = srv.handle();
    for (label, budget, clients) in [
        ("pool, rich budget, 4 clients", f64::INFINITY, 4usize),
        ("pool, 2-bit budget, 4 clients", 0.001, 4),
        ("pool, rich budget, 16 clients", f64::INFINITY, 16),
    ] {
        drive(&h, &ds, &format!("{label} ({n_workers}w)"), budget, clients);
    }
    println!("{}", h.metrics().report());
    srv.shutdown();
}
