//! Serving-loop benchmark: batching throughput and latency percentiles
//! over the native integer engine (and PJRT when artifacts exist).

use pann::coordinator::{EnginePoint, Server, ServerConfig};
use pann::coordinator::server::NativeEngine;
use pann::data::{synth, Dataset};
use pann::nn::eval::batch_tensor;
use pann::nn::quantized::{QuantConfig, QuantizedModel};
use pann::nn::Model;
use pann::quant::ActQuantMethod;
use std::time::Duration;

fn native_points() -> anyhow::Result<Vec<EnginePoint>> {
    let mut model = Model::reference_cnn(1);
    let ds = Dataset::from_synth(synth::digits(64, 2));
    let stats_x = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats_x)?;
    let mut points = Vec::new();
    for (bits, bx, r) in [(2u32, 6u32, 10.0 / 6.0 - 0.5), (4, 7, 24.0 / 7.0 - 0.5), (8, 8, 7.5)] {
        let qm = QuantizedModel::prepare(&model, QuantConfig::pann(bx, r, ActQuantMethod::BnStats), None)?;
        let gf = pann::power::model::mac_power_unsigned_total(bits) * model.num_macs() as f64 / 1e9;
        points.push(EnginePoint {
            name: format!("pann-p{bits}"),
            giga_flips_per_sample: gf,
            engine: Box::new(NativeEngine { qm, sample_shape: vec![1, 16, 16] }),
        });
    }
    Ok(points)
}

fn main() {
    let srv = Server::start(
        native_points,
        256,
        ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(500),
            budget_gflips: f64::INFINITY,
        },
    )
    .expect("server start");
    let h = srv.handle();
    let ds = Dataset::from_synth(synth::digits(256, 5));

    for (label, budget, clients) in [
        ("rich budget, 4 clients", f64::INFINITY, 4usize),
        ("2-bit budget, 4 clients", 0.001, 4),
        ("rich budget, 16 clients", f64::INFINITY, 16),
    ] {
        h.set_budget(budget);
        let t0 = std::time::Instant::now();
        let n_per = 64usize;
        std::thread::scope(|s| {
            for c in 0..clients {
                let h = h.clone();
                let ds = &ds;
                s.spawn(move || {
                    for i in 0..n_per {
                        let idx = (c * n_per + i) % ds.len();
                        h.infer(ds.sample(idx).to_vec()).expect("infer");
                    }
                });
            }
        });
        let dt = t0.elapsed().as_secs_f64();
        let total = clients * n_per;
        println!(
            "{label:<28} {total} reqs in {dt:.3}s = {:.0} req/s",
            total as f64 / dt
        );
    }
    println!("{}", h.metrics().report());
    srv.shutdown();
}
