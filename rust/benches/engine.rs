//! Hot-path benchmarks: GEMM kernels (scalar vs blocked vs threaded vs
//! SIMD-dispatched), im2col, and batched quantized engine throughput
//! per operating point, single- vs multi-core and SIMD vs forced
//! scalar.
//!
//! Emits `BENCH_engine.json` (schema `bench-engine/v2`: ops/sec and
//! GFlips/sample per operating point, per-kernel SIMD speedups, plus
//! every micro-bench) so later PRs can track the perf trajectory
//! without parsing stdout. See EXPERIMENTS.md §SIMD for the
//! measurement protocol and field glossary.

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::data::{synth, Dataset};
use pann::nn::eval::{batch_tensor, n_threads};
use pann::nn::gemm::{self, SimdLevel};
use pann::nn::quantized::{QuantConfig, QuantizedModel};
use pann::nn::{ExecutionPlan, Model, Scratch};
use pann::quant::ActQuantMethod;
use pann::util::bench::{run, write_json};
use pann::util::{Json, Rng};

fn main() {
    let mut report: Vec<(String, Json)> = Vec::new();
    let mut r = Rng::new(1);
    let simd = gemm::active_level();
    println!("simd level: {}", simd.name());

    // --- GEMM kernels, small (one conv layer at batch 1) ---
    let (m, n, k) = (256, 64, 144);
    let a_f: Vec<f32> = (0..m * k).map(|_| r.normal() as f32).collect();
    let b_f: Vec<f32> = (0..n * k).map(|_| r.normal() as f32).collect();
    let mut out_f = vec![0.0f32; m * n];
    let gemm_flops = 2.0 * (m * n * k) as f64;
    let res = run("gemm_f32 256x64x144", || {
        gemm::gemm_f32(
            std::hint::black_box(&a_f),
            std::hint::black_box(&b_f),
            &mut out_f,
            m,
            n,
            k,
        );
    });
    println!("  -> {:.2} GFLOP/s", res.throughput(gemm_flops) / 1e9);
    report.push((res.name.clone(), res.to_json()));

    let a_i: Vec<i32> = (0..m * k).map(|_| r.range_i64(0, 64) as i32).collect();
    let b_i: Vec<i32> = (0..n * k).map(|_| r.range_i64(-8, 8) as i32).collect();
    let pos: Vec<i32> = b_i.iter().map(|&v| v.max(0)).collect();
    let neg: Vec<i32> = b_i.iter().map(|&v| (-v).max(0)).collect();
    let mut out_i = vec![0i64; m * n];
    let res = run("gemm_i32 256x64x144", || {
        gemm::gemm_i32(
            std::hint::black_box(&a_i),
            std::hint::black_box(&b_i),
            &mut out_i,
            m,
            n,
            k,
        );
    });
    println!("  -> {:.2} Gmac/s", res.throughput((m * n * k) as f64) / 1e9);
    report.push((res.name.clone(), res.to_json()));
    let res = run("gemm_i32_split 256x64x144", || {
        gemm::gemm_i32_split(
            std::hint::black_box(&a_i),
            std::hint::black_box(&pos),
            std::hint::black_box(&neg),
            &mut out_i,
            m,
            n,
            k,
        );
    });
    println!("  -> {:.2} Gmac/s (dual bank)", res.throughput((m * n * k) as f64) / 1e9);
    report.push((res.name.clone(), res.to_json()));

    // --- blocked kernels, batched (one conv layer at batch 64):
    //     scalar dispatch vs the detected SIMD level, per variant ---
    let threads = n_threads();
    let (bm, bn, bk) = (64 * 256, 64, 144);
    let ba: Vec<i32> = (0..bm * bk).map(|_| r.range_i64(0, 64) as i32).collect();
    let bw: Vec<i32> = (0..bn * bk).map(|_| r.range_i64(-8, 8) as i32).collect();
    let bpos: Vec<i32> = bw.iter().map(|&v| v.max(0)).collect();
    let bneg: Vec<i32> = bw.iter().map(|&v| (-v).max(0)).collect();
    let ba16: Vec<i16> = ba.iter().map(|&v| v as i16).collect();
    let bw16: Vec<i16> = bw.iter().map(|&v| v as i16).collect();
    let mut bout = vec![0i64; bm * bn];
    let macs = (bm * bn * bk) as f64;
    let mut kernel_speedups: Vec<(String, Json)> = Vec::new();
    {
        // each variant timed at scalar then at the detected level, at
        // 1 thread so the ratio isolates vectorization from core
        // scaling
        let mut bench_pair = |name: &str, f: &mut dyn FnMut(SimdLevel)| {
            let rs = run(&format!("gemm {name} 16384x64x144 scalar t=1"), || f(SimdLevel::Scalar));
            let rv = run(&format!("gemm {name} 16384x64x144 {} t=1", simd.name()), || f(simd));
            let speedup = rs.mean_ns / rv.mean_ns;
            println!(
                "  {name}: {:.2} -> {:.2} Gmac/s ({speedup:.2}x {})",
                rs.throughput(macs) / 1e9,
                rv.throughput(macs) / 1e9,
                simd.name()
            );
            report.push((format!("gemm_{name}_batch64_scalar_1t"), rs.to_json()));
            report.push((format!("gemm_{name}_batch64_simd_1t"), rv.to_json()));
            kernel_speedups.push((
                name.to_string(),
                Json::obj(vec![
                    ("gmacs_scalar_1t", Json::Num(rs.throughput(macs) / 1e9)),
                    ("gmacs_simd_1t", Json::Num(rv.throughput(macs) / 1e9)),
                    ("simd_speedup_1t", Json::Num(speedup)),
                ]),
            ));
        };
        bench_pair("wide", &mut |l| {
            gemm::gemm_i32_blocked_at(
                l,
                std::hint::black_box(&ba),
                std::hint::black_box(&bw),
                &mut bout,
                bm,
                bn,
                bk,
                1,
            )
        });
        bench_pair("narrow", &mut |l| {
            gemm::gemm_i32_narrow_blocked_at(
                l,
                std::hint::black_box(&ba),
                std::hint::black_box(&bw),
                &mut bout,
                bm,
                bn,
                bk,
                1,
            )
        });
        bench_pair("split_wide", &mut |l| {
            gemm::gemm_i32_split_blocked_at(
                l,
                std::hint::black_box(&ba),
                std::hint::black_box(&bpos),
                std::hint::black_box(&bneg),
                &mut bout,
                bm,
                bn,
                bk,
                1,
            )
        });
        bench_pair("split_narrow", &mut |l| {
            gemm::gemm_i32_split_narrow_blocked_at(
                l,
                std::hint::black_box(&ba),
                std::hint::black_box(&bpos),
                std::hint::black_box(&bneg),
                &mut bout,
                bm,
                bn,
                bk,
                1,
            )
        });
        bench_pair("narrow_packed_i16", &mut |l| {
            gemm::gemm_i16_narrow_blocked_at(
                l,
                std::hint::black_box(&ba16),
                std::hint::black_box(&bw16),
                &mut bout,
                bm,
                bn,
                bk,
                1,
            )
        });
    }
    // thread scaling on the split kernel, at the detected level
    let res1 = run("gemm_i32_split_blocked 16384x64x144 t=1", || {
        gemm::gemm_i32_split_blocked(
            std::hint::black_box(&ba),
            std::hint::black_box(&bpos),
            std::hint::black_box(&bneg),
            &mut bout,
            bm,
            bn,
            bk,
            1,
        );
    });
    report.push(("gemm_split_batch64_blocked_1t".into(), res1.to_json()));
    let rest = run(&format!("gemm_i32_split_blocked 16384x64x144 t={threads}"), || {
        gemm::gemm_i32_split_blocked(
            std::hint::black_box(&ba),
            std::hint::black_box(&bpos),
            std::hint::black_box(&bneg),
            &mut bout,
            bm,
            bn,
            bk,
            threads,
        );
    });
    let kernel_speedup = res1.mean_ns / rest.mean_ns;
    println!(
        "  -> {:.2} Gmac/s ({kernel_speedup:.2}x over 1 thread)",
        rest.throughput(macs) / 1e9
    );
    report.push(("gemm_split_batch64_blocked_mt".into(), rest.to_json()));

    // --- im2col ---
    let x: Vec<f32> = (0..8 * 16 * 16).map(|_| r.f32()).collect();
    let mut cols = Vec::new();
    let res = run("im2col 8ch 16x16 k3", || {
        gemm::im2col(std::hint::black_box(&x), 8, 16, 16, 3, 3, 1, 1, &mut cols);
    });
    report.push((res.name.clone(), res.to_json()));

    // --- batched engine forward, per operating point: 1 vs N cores,
    //     and SIMD plan vs its forced-scalar twin ---
    let mut model = Model::reference_cnn(1);
    let ds = Dataset::from_synth(synth::digits(256, 2));
    let stats_x = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats_x).unwrap();
    let batch = 64usize;
    let xb = batch_tensor(&ds, 0, batch);
    let mut points = Vec::new();
    for (name, cfg) in [
        ("unsigned-4bit", QuantConfig::unsigned_baseline(4, ActQuantMethod::BnStats)),
        ("pann-bx6-r2", QuantConfig::pann(6, 2.0, ActQuantMethod::BnStats)),
    ] {
        let plan = ExecutionPlan::compile(&model, cfg, None).unwrap();
        let mut scalar_plan = ExecutionPlan::compile(&model, cfg, None).unwrap();
        scalar_plan.force_scalar();
        let mut scratch = Scratch::for_plan(&plan, batch);
        // energy per sample at this operating point
        let mut meter = plan.new_meter();
        plan.forward_batch(&xb, &mut scratch, &mut meter, 1).unwrap();
        let gflips_per_sample = meter.giga() / batch as f64;

        let r1 = run(&format!("engine {name} batch{batch} t=1"), || {
            let mut meter = plan.new_meter();
            let y = plan
                .forward_batch(std::hint::black_box(&xb), &mut scratch, &mut meter, 1)
                .unwrap();
            std::hint::black_box(y.data.len());
        });
        let ops1 = r1.throughput(batch as f64);
        println!("  -> {ops1:.0} samples/s single-core");
        let rs = run(&format!("engine {name} batch{batch} t=1 forced-scalar"), || {
            let mut meter = scalar_plan.new_meter();
            let y = scalar_plan
                .forward_batch(std::hint::black_box(&xb), &mut scratch, &mut meter, 1)
                .unwrap();
            std::hint::black_box(y.data.len());
        });
        let ops_scalar = rs.throughput(batch as f64);
        let simd_speedup = ops1 / ops_scalar;
        println!("  -> {ops_scalar:.0} samples/s forced-scalar ({simd_speedup:.2}x from simd)");
        let rt = run(&format!("engine {name} batch{batch} t={threads}"), || {
            let mut meter = plan.new_meter();
            let y = plan
                .forward_batch(std::hint::black_box(&xb), &mut scratch, &mut meter, threads)
                .unwrap();
            std::hint::black_box(y.data.len());
        });
        let opst = rt.throughput(batch as f64);
        let speedup = opst / ops1;
        println!("  -> {opst:.0} samples/s on {threads} threads ({speedup:.2}x)");
        report.push((format!("engine_{name}_1t"), r1.to_json()));
        report.push((format!("engine_{name}_scalar_1t"), rs.to_json()));
        report.push((format!("engine_{name}_mt"), rt.to_json()));
        points.push(Json::obj(vec![
            ("point", Json::from(name)),
            ("batch", Json::from(batch)),
            ("threads", Json::from(threads)),
            ("ops_per_sec_1t", Json::Num(ops1)),
            ("ops_per_sec_scalar_1t", Json::Num(ops_scalar)),
            ("simd_speedup_1t", Json::Num(simd_speedup)),
            ("ops_per_sec_mt", Json::Num(opst)),
            ("speedup", Json::Num(speedup)),
            ("gflips_per_sample", Json::Num(gflips_per_sample)),
        ]));
    }

    // --- end-to-end eval loops (outer parallelism, plan API inside) ---
    for (name, cfg) in [
        ("eval unsigned 4-bit", QuantConfig::unsigned_baseline(4, ActQuantMethod::BnStats)),
        ("eval pann b̃x=6 R=2", QuantConfig::pann(6, 2.0, ActQuantMethod::BnStats)),
    ] {
        let qm = QuantizedModel::prepare(&model, cfg, None).unwrap();
        let res = run(name, || {
            let r = pann::nn::eval::eval_quantized(std::hint::black_box(&qm), &ds).unwrap();
            std::hint::black_box(r.correct);
        });
        let macs = model.num_macs() as f64 * ds.len() as f64;
        println!("  -> {:.2} Gmac/s end-to-end", res.throughput(macs) / 1e9);
        report.push((name.to_string(), res.to_json()));
    }

    let doc = Json::obj(vec![
        ("schema", Json::from("bench-engine/v2")),
        ("simd_level", Json::from(simd.name())),
        ("threads", Json::from(threads)),
        ("kernel_speedups", Json::Obj(kernel_speedups.into_iter().collect())),
        ("engine_points", Json::Arr(points)),
        (
            "cases",
            Json::Obj(report.into_iter().collect()),
        ),
    ]);
    write_json("BENCH_engine.json", &doc).expect("write BENCH_engine.json");
    println!("\nwrote BENCH_engine.json");
}
