//! Hot-path micro-benchmarks: GEMM kernels, im2col, quantized layer
//! execution, full-model evaluation throughput.

use pann::data::{synth, Dataset};
use pann::nn::eval::{batch_tensor, eval_quantized};
use pann::nn::gemm;
use pann::nn::quantized::{QuantConfig, QuantizedModel};
use pann::nn::Model;
use pann::quant::ActQuantMethod;
use pann::util::bench::run;
use pann::util::Rng;

fn main() {
    let mut r = Rng::new(1);
    // --- GEMM kernels ---
    let (m, n, k) = (256, 64, 144);
    let a_f: Vec<f32> = (0..m * k).map(|_| r.normal() as f32).collect();
    let b_f: Vec<f32> = (0..n * k).map(|_| r.normal() as f32).collect();
    let mut out_f = vec![0.0f32; m * n];
    let gemm_flops = 2.0 * (m * n * k) as f64;
    let res = run("gemm_f32 256x64x144", || {
        gemm::gemm_f32(
            std::hint::black_box(&a_f),
            std::hint::black_box(&b_f),
            &mut out_f,
            m,
            n,
            k,
        );
    });
    println!("  -> {:.2} GFLOP/s", res.throughput(gemm_flops) / 1e9);

    let a_i: Vec<i32> = (0..m * k).map(|_| r.range_i64(0, 64) as i32).collect();
    let b_i: Vec<i32> = (0..n * k).map(|_| r.range_i64(-8, 8) as i32).collect();
    let pos: Vec<i32> = b_i.iter().map(|&v| v.max(0)).collect();
    let neg: Vec<i32> = b_i.iter().map(|&v| (-v).max(0)).collect();
    let mut out_i = vec![0i64; m * n];
    let res = run("gemm_i32 256x64x144", || {
        gemm::gemm_i32(
            std::hint::black_box(&a_i),
            std::hint::black_box(&b_i),
            &mut out_i,
            m,
            n,
            k,
        );
    });
    println!("  -> {:.2} Gmac/s", res.throughput((m * n * k) as f64) / 1e9);
    let res = run("gemm_i32_split 256x64x144", || {
        gemm::gemm_i32_split(
            std::hint::black_box(&a_i),
            std::hint::black_box(&pos),
            std::hint::black_box(&neg),
            &mut out_i,
            m,
            n,
            k,
        );
    });
    println!("  -> {:.2} Gmac/s (dual bank)", res.throughput((m * n * k) as f64) / 1e9);

    // --- im2col ---
    let x: Vec<f32> = (0..8 * 16 * 16).map(|_| r.f32()).collect();
    let mut cols = Vec::new();
    run("im2col 8ch 16x16 k3", || {
        gemm::im2col(std::hint::black_box(&x), 8, 16, 16, 3, 3, 1, 1, &mut cols);
    });

    // --- full quantized model eval ---
    let mut model = Model::reference_cnn(1);
    let ds = Dataset::from_synth(synth::digits(256, 2));
    let stats_x = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats_x).unwrap();
    for (name, cfg) in [
        ("eval unsigned 4-bit", QuantConfig::unsigned_baseline(4, ActQuantMethod::BnStats)),
        ("eval pann b̃x=6 R=2", QuantConfig::pann(6, 2.0, ActQuantMethod::BnStats)),
    ] {
        let qm = QuantizedModel::prepare(&model, cfg, None).unwrap();
        let res = run(name, || {
            let r = eval_quantized(std::hint::black_box(&qm), &ds).unwrap();
            std::hint::black_box(r.correct);
        });
        let macs = model.num_macs() as f64 * ds.len() as f64;
        println!("  -> {:.2} Gmac/s end-to-end", res.throughput(macs) / 1e9);
    }
}
