//! Toggle-simulator throughput (instructions simulated per second) —
//! the cost of regenerating the paper's measurement figures.

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::bitflip::{gates, BoothMultiplier, MacUnit, Multiplier, PannDatapath, SerialMultiplier};
use pann::util::bench::run;
use pann::util::Rng;

fn main() {
    let mut r = Rng::new(3);
    let ws: Vec<i64> = (0..4096).map(|_| r.range_i64(-128, 128)).collect();
    let xs: Vec<i64> = (0..4096).map(|_| r.range_i64(-128, 128)).collect();

    let mut booth = BoothMultiplier::new(8, true);
    let mut i = 0;
    let res = run("booth 8x8 signed mul", || {
        let (p, _) = booth.mul(ws[i & 4095], xs[i & 4095]);
        std::hint::black_box(p);
        i += 1;
    });
    println!("  -> {:.2} Mops/s", res.throughput(1.0) / 1e6);

    let mut serial = SerialMultiplier::new(8, true);
    let mut i = 0;
    run("serial 8x8 signed mul", || {
        let (p, _) = serial.mul(ws[i & 4095], xs[i & 4095]);
        std::hint::black_box(p);
        i += 1;
    });

    let mut mac = MacUnit::new(BoothMultiplier::new(8, true), 32);
    let mut i = 0;
    run("mac 8x8 B=32", || {
        std::hint::black_box(mac.mac(ws[i & 4095], xs[i & 4095]).paper_total());
        i += 1;
    });

    let mut dp = PannDatapath::new(6, 32);
    let qx: Vec<i64> = (0..4096).map(|_| r.range_i64(0, 64)).collect();
    let mut i = 0;
    run("pann element R=3", || {
        std::hint::black_box(dp.element(3, qx[i & 4095]).paper_total());
        i += 1;
    });

    // gate level
    let mut circ = gates::MultCircuit::new_signed(4);
    let mut i = 0;
    let res = run("gate-level 4x4 signed mul", || {
        let (p, _) = circ.mul_words(
            pann::bitflip::word::to_word(ws[i & 4095] % 8, 8),
            pann::bitflip::word::to_word(xs[i & 4095] % 8, 8),
        );
        std::hint::black_box(p);
        i += 1;
    });
    println!("  -> {:.2} Mops/s (gate level)", res.throughput(1.0) / 1e6);
}
