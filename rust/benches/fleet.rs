//! Fleet serving benchmark: two compiled menus registered on one
//! worker pool under one energy envelope, driven by a *skewed*
//! two-model load — a flooding "hot" model and a paced "cold" one —
//! and measured for exactly the fleet claims: per-model throughput,
//! per-model frontier residency, and envelope tracking error.
//!
//! The acceptance shape: the hot model must end the flood on a cheaper
//! point of *its* frontier, while the cold model keeps serving its most
//! accurate point throughout (demand-weighted max-min arbitration — see
//! `coordinator/registry.rs`).
//!
//! Emits `BENCH_fleet.json` (schema `bench-fleet/v1`): envelope +
//! window, then one record per model with requests, achieved req/s,
//! the point serving at the end, governor residency/switches/tracking
//! error, and the arbiter's final demand estimate and envelope share.

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::coordinator::{EnergyEnvelope, InferRequest, Menu, ServerBuilder};
use pann::data::{synth, Dataset};
use pann::nn::eval::batch_tensor;
use pann::nn::Model;
use pann::pann::compile_menu;
use pann::quant::ActQuantMethod;
use pann::util::bench::write_json;
use pann::util::Json;
use std::time::{Duration, Instant};

fn compiled_menu(seed: u64) -> (Model, Dataset, pann::pann::MenuArtifact) {
    let mut model = Model::reference_cnn(seed);
    let ds = Dataset::from_synth(synth::digits(192, seed + 1));
    let stats = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats).expect("record stats");
    let menu = compile_menu(&model, &[2, 8], ActQuantMethod::BnStats, None, &ds.take(48), 2..=8)
        .expect("compile menu");
    (model, ds, menu)
}

fn main() {
    let (hot_model, hot_ds, hot_menu) = compiled_menu(3);
    let (cold_model, cold_ds, cold_menu) = compiled_menu(23);
    let hot_rich = hot_menu.points.last().expect("hot menu").gflips_per_sample;
    let cold_rich = cold_menu.points.last().expect("cold menu").gflips_per_sample;
    println!(
        "hot menu: {} points (rich {hot_rich:.6} GF/sample); cold menu: {} points (rich {cold_rich:.6} GF/sample)",
        hot_menu.points.len(),
        cold_menu.points.len()
    );

    // Cold is paced at ~40 req/s; the arbiter prices its need at
    // rate × rich × DEMAND_HEADROOM, so the envelope must leave the
    // *equal* max-min share above that need (×2.2 margin) for cold to
    // be satisfied in full — plus ~25 rich-requests/sec for hot, which
    // the flood exceeds by orders of magnitude and must breach.
    let cold_pace = Duration::from_millis(25);
    let envelope_rate =
        cold_rich * 40.0 * pann::coordinator::registry::DEMAND_HEADROOM * 2.2 + hot_rich * 25.0;
    let window = Duration::from_millis(20);
    let srv = ServerBuilder::new()
        .workers(2)
        .max_batch(8)
        .queue_depth(1024)
        .envelope(EnergyEnvelope::gflips_per_sec(envelope_rate))
        .governor_window(window)
        .governor_hysteresis(1)
        .register(
            "hot",
            Menu::shared(hot_menu.shared_points(&hot_model, None, 8).expect("hot points")),
        )
        .register(
            "cold",
            Menu::shared(cold_menu.shared_points(&cold_model, None, 8).expect("cold points")),
        )
        .serve_fleet()
        .expect("serve fleet");
    let client = srv.client();

    // Skewed load, concurrently: hot floods 600 requests, cold paces 40.
    let (hot_stats, cold_stats) = std::thread::scope(|s| {
        let hc = client.clone();
        let hds = &hot_ds;
        let hot = s.spawn(move || {
            let t0 = Instant::now();
            let n = 600usize;
            let mut last = String::new();
            for i in 0..n {
                let r = hc
                    .submit(InferRequest::new(hds.sample(i % hds.len()).to_vec()).model("hot"))
                    .expect("submit hot")
                    .wait()
                    .expect("hot response");
                last = r.point;
            }
            (n, t0.elapsed().as_secs_f64(), last)
        });
        let cc = client.clone();
        let cds = &cold_ds;
        let cold = s.spawn(move || {
            let t0 = Instant::now();
            let n = 40usize;
            let mut last = String::new();
            for i in 0..n {
                let r = cc
                    .submit(InferRequest::new(cds.sample(i % cds.len()).to_vec()).model("cold"))
                    .expect("submit cold")
                    .wait()
                    .expect("cold response");
                last = r.point;
                std::thread::sleep(cold_pace);
            }
            (n, t0.elapsed().as_secs_f64(), last)
        });
        (hot.join().expect("hot thread"), cold.join().expect("cold thread"))
    });

    let fleet = client.fleet().expect("fleet snapshot");
    print!("{}", fleet.report());
    let metrics = client.metrics();
    println!("{} point switches (metrics view)", metrics.point_switches);

    let model_record = |name: &str, stats: (usize, f64, String)| {
        let (n, secs, end_point) = stats;
        let status = fleet
            .models
            .iter()
            .find(|m| m.name == name)
            .expect("model in fleet snapshot");
        let gov = status.governor.as_ref().expect("governed model");
        let residency: Vec<Json> = gov
            .residency
            .iter()
            .map(|(point, windows)| {
                Json::obj(vec![
                    ("point", Json::from(point.as_str())),
                    ("windows", Json::from(*windows as usize)),
                ])
            })
            .collect();
        println!(
            "model {name:<5} {n:>4} reqs in {secs:.2}s = {:>7.0} req/s, ends on {end_point} \
             (share {:.4} GF/s, demand {:.1}/s)",
            n as f64 / secs.max(1e-9),
            status.envelope_share.unwrap_or(f64::NAN),
            status.demand_rate.unwrap_or(f64::NAN),
        );
        Json::obj(vec![
            ("model", Json::from(name)),
            ("requests", Json::from(n)),
            ("secs", Json::Num(secs)),
            ("rps", Json::Num(n as f64 / secs.max(1e-9))),
            ("end_point", Json::from(end_point.as_str())),
            ("menu_points", Json::from(status.points)),
            ("residency", Json::Arr(residency)),
            ("switches", Json::from(gov.switches as usize)),
            ("windows", Json::from(gov.windows as usize)),
            (
                "mean_tracking_error",
                gov.mean_tracking_error.map_or(Json::Null, Json::Num),
            ),
            (
                "envelope_share_gflips_per_sec",
                status.envelope_share.map_or(Json::Null, Json::Num),
            ),
            (
                "demand_rate_samples_per_sec",
                status.demand_rate.map_or(Json::Null, Json::Num),
            ),
        ])
    };
    let doc = Json::obj(vec![
        ("schema", Json::from("bench-fleet/v1")),
        ("envelope_gflips_per_sec", Json::Num(envelope_rate)),
        ("window_ms", Json::Num(window.as_secs_f64() * 1e3)),
        ("hysteresis", Json::from(1usize)),
        (
            "models",
            Json::Arr(vec![
                model_record("hot", hot_stats),
                model_record("cold", cold_stats),
            ]),
        ),
        (
            "measured_minus_modeled_gflips",
            Json::Num(metrics.measured_minus_modeled_gflips),
        ),
    ]);
    write_json("BENCH_fleet.json", &doc).expect("write BENCH_fleet.json");
    println!("wrote BENCH_fleet.json");
    srv.shutdown();
}
