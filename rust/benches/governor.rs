//! Closed-loop governor benchmark: compile a real menu, serve it
//! under an energy envelope, drive a load ramp (idle → flood → idle)
//! and record how the governor walks the frontier — per-point
//! residency, switch count, and the envelope tracking error.
//!
//! Emits `BENCH_governor.json` (schema `bench-governor/v1`: envelope
//! + window, one record per ramp phase with the achieved request rate
//! and the point serving at phase end, plus the governor's residency /
//! switches / mean tracking error and the per-point *measured*
//! Gflips/sample ledger) — the closed-loop counterpart of
//! `BENCH_coordinator.json`.

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::coordinator::{EnergyEnvelope, Menu, ServerBuilder};
use pann::data::{synth, Dataset};
use pann::nn::eval::batch_tensor;
use pann::nn::Model;
use pann::pann::compile_menu;
use pann::quant::ActQuantMethod;
use pann::util::bench::write_json;
use pann::util::Json;
use std::time::{Duration, Instant};

struct Phase {
    name: &'static str,
    requests: usize,
    /// Inter-arrival gap (None = flood as fast as responses return).
    gap: Option<Duration>,
    /// Idle pause before the phase starts.
    lead_in: Duration,
}

fn main() {
    let mut model = Model::reference_cnn(3);
    let ds = Dataset::from_synth(synth::digits(256, 4));
    let stats = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats).expect("record stats");
    let menu = compile_menu(&model, &[2, 4, 8], ActQuantMethod::BnStats, None, &ds.take(64), 2..=8)
        .expect("compile menu");
    let rich_cost = menu.points.last().expect("non-empty menu").gflips_per_sample;
    println!("menu: {} frontier points, richest {rich_cost:.6} GF/sample", menu.points.len());

    // Envelope: 25 requests/sec worth of the *richest* point. The
    // low-rate phases fit comfortably at full accuracy; the flood
    // phase exceeds it by orders of magnitude and must force the
    // governor down the frontier.
    let envelope_rate = rich_cost * 25.0;
    let window = Duration::from_millis(20);
    let hysteresis = 1u32;
    let srv = ServerBuilder::new()
        .workers(2)
        .max_batch(8)
        .queue_depth(1024)
        .envelope(EnergyEnvelope::gflips_per_sec(envelope_rate))
        .governor_window(window)
        .governor_hysteresis(hysteresis)
        .serve(Menu::shared(
            menu.shared_points(&model, None, 8).expect("recompile menu"),
        ))
        .expect("serve menu");
    let client = srv.client();

    let phases = [
        Phase {
            name: "light",
            requests: 12,
            gap: Some(Duration::from_millis(25)),
            lead_in: Duration::ZERO,
        },
        Phase {
            name: "flood",
            requests: 600,
            gap: None,
            lead_in: Duration::ZERO,
        },
        Phase {
            name: "recovery",
            requests: 4,
            gap: Some(Duration::from_millis(150)),
            lead_in: Duration::from_millis(300),
        },
    ];

    let mut phase_records: Vec<Json> = Vec::new();
    for ph in &phases {
        std::thread::sleep(ph.lead_in);
        let t0 = Instant::now();
        let mut last_point = String::new();
        for i in 0..ph.requests {
            let r = client
                .infer(ds.sample(i % ds.len()).to_vec())
                .expect("governed request");
            last_point = r.point;
            if let Some(gap) = ph.gap {
                std::thread::sleep(gap);
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let rps = ph.requests as f64 / secs.max(1e-9);
        println!(
            "phase {:<9} {:>4} reqs in {secs:.2}s = {rps:>7.0} req/s, ends on {last_point}",
            ph.name, ph.requests
        );
        phase_records.push(Json::obj(vec![
            ("name", Json::from(ph.name)),
            ("requests", Json::from(ph.requests)),
            ("secs", Json::Num(secs)),
            ("rps", Json::Num(rps)),
            ("end_point", Json::from(last_point.as_str())),
        ]));
    }

    let gov = client.governor().expect("governor active");
    print!("{}", gov.report());
    let metrics = client.metrics();
    println!("{} point switches (metrics view)", metrics.point_switches);

    let residency: Vec<Json> = gov
        .residency
        .iter()
        .map(|(name, windows)| {
            Json::obj(vec![
                ("point", Json::from(name.as_str())),
                ("windows", Json::from(*windows as usize)),
            ])
        })
        .collect();
    let measured: Vec<Json> = gov
        .measured_gflips_per_sample
        .iter()
        .map(|(name, gf)| {
            Json::obj(vec![
                ("point", Json::from(name.as_str())),
                (
                    "measured_gflips_per_sample",
                    gf.map_or(Json::Null, Json::Num),
                ),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::from("bench-governor/v1")),
        ("envelope_gflips_per_sec", Json::Num(envelope_rate)),
        ("window_ms", Json::Num(window.as_secs_f64() * 1e3)),
        ("hysteresis", Json::from(hysteresis as usize)),
        ("menu_points", Json::from(gov.residency.len())),
        ("phases", Json::Arr(phase_records)),
        ("residency", Json::Arr(residency)),
        ("switches", Json::from(gov.switches as usize)),
        ("windows", Json::from(gov.windows as usize)),
        (
            "mean_tracking_error",
            gov.mean_tracking_error.map_or(Json::Null, Json::Num),
        ),
        ("measured", Json::Arr(measured)),
        (
            "measured_minus_modeled_gflips",
            Json::Num(metrics.measured_minus_modeled_gflips),
        ),
    ]);
    write_json("BENCH_governor.json", &doc).expect("write BENCH_governor.json");
    println!("wrote BENCH_governor.json");
    srv.shutdown();
}
