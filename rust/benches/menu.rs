//! Menu-compiler benchmark: how long compiling + Pareto-pruning the
//! power–accuracy frontier takes — uniform sweep vs the per-layer
//! mixed-precision search — how long reloading the artifact takes, and
//! how dense each frontier comes out.
//!
//! Emits `BENCH_menu.json` (schema `bench-menu/v2`: uniform and mixed
//! compile wall-clock, candidates swept, points kept vs pruned,
//! frontier density, plus the mixed frontier itself) so later PRs can
//! track the menu-compilation trajectory without parsing stdout — the
//! compile-time counterpart of `BENCH_engine.json` /
//! `BENCH_coordinator.json`.

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::data::{synth, Dataset};
use pann::nn::eval::batch_tensor;
use pann::nn::Model;
use pann::pann::{compile_menu, compile_menu_per_layer, MenuArtifact, PerLayerSearch};
use pann::quant::ActQuantMethod;
use pann::util::bench::{stamped, write_json};
use pann::util::Json;
use std::time::Instant;

fn main() {
    let mut model = Model::reference_cnn(1);
    let ds = Dataset::from_synth(synth::digits(256, 2));
    let stats_x = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats_x).expect("record stats");
    let val = ds.take(96);
    let budget_bits = [2u32, 4, 8];

    // --- uniform compile: sweep all curves, evaluate, prune ---
    let t0 = Instant::now();
    let uniform = compile_menu(&model, &budget_bits, ActQuantMethod::BnStats, None, &val, 2..=8)
        .expect("compile uniform menu");
    let uniform_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "compile-menu uniform (bits {budget_bits:?}, {} val samples): {uniform_ms:.1} ms — \
         swept {}, kept {}, pruned {}",
        val.len(),
        uniform.swept,
        uniform.points.len(),
        uniform.pruned()
    );

    // --- mixed compile: same sweep + sensitivity-guided per-layer
    // search, pruned over the candidate union ---
    let t1 = Instant::now();
    let mixed = compile_menu_per_layer(
        &model,
        &budget_bits,
        ActQuantMethod::BnStats,
        None,
        &val,
        2..=8,
        PerLayerSearch::default(),
    )
    .expect("compile mixed menu");
    let mixed_ms = t1.elapsed().as_secs_f64() * 1e3;
    let mixed_points = mixed.points.iter().filter(|p| p.layer_bits.is_some()).count();
    println!(
        "compile-menu --per-layer: {mixed_ms:.1} ms — swept {}, kept {} ({} mixed), pruned {}",
        mixed.swept,
        mixed.points.len(),
        mixed_points,
        mixed.pruned()
    );
    for line in mixed.frontier_lines() {
        println!("  {line}");
    }
    // the headline property the test battery proves, kept visible in
    // the bench artifact: the mixed frontier is at least as dense
    assert!(
        mixed.points.len() >= uniform.points.len(),
        "mixed frontier ({}) must be at least as dense as uniform ({})",
        mixed.points.len(),
        uniform.points.len()
    );

    // --- artifact round trip: save, load, recompile for serving
    // (through the per-layer path, since the menu carries mixed
    // points) ---
    let dir = std::env::temp_dir().join("pann_bench_menu");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("menu.json");
    mixed.save(&path).expect("save menu");
    let t2 = Instant::now();
    let loaded = MenuArtifact::load(&path).expect("load menu");
    let points = loaded.shared_points(&model, None, 16).expect("recompile menu");
    let reload_ms = t2.elapsed().as_secs_f64() * 1e3;
    assert_eq!(points.len(), mixed.points.len());
    println!("reload + recompile from {}: {reload_ms:.1} ms", path.display());

    let frontier: Vec<Json> = mixed
        .points
        .iter()
        .map(|p| {
            let mut fields = vec![
                ("name", Json::from(p.name.as_str())),
                ("bx_tilde", Json::from(p.bx_tilde as usize)),
                ("r", Json::Num(p.r)),
                ("gflips_per_sample", Json::Num(p.gflips_per_sample)),
                ("val_acc", Json::Num(p.val_acc)),
            ];
            if let Some(bits) = &p.layer_bits {
                fields.push((
                    "layer_bits",
                    Json::Arr(bits.iter().map(|&b| Json::from(b as usize)).collect()),
                ));
            }
            Json::obj(fields)
        })
        .collect();
    let side = |menu: &MenuArtifact, compile_ms: f64| {
        Json::obj(vec![
            ("compile_ms", Json::Num(compile_ms)),
            ("swept", Json::from(menu.swept)),
            ("kept", Json::from(menu.points.len())),
            ("pruned", Json::from(menu.pruned())),
            (
                "frontier_density",
                Json::Num(menu.points.len() as f64 / menu.swept as f64),
            ),
        ])
    };
    let doc = stamped(
        "bench-menu/v2",
        "cargo bench --bench menu — reference_cnn(1), synth digits(256,2), 96 val samples; \
         compile/reload wall times are machine-dependent, the swept/kept counts and the \
         frontier itself are deterministic functions of the build",
        vec![
            ("budget_bits", Json::nums(budget_bits.iter().map(|&b| b as f64))),
            ("val_samples", Json::from(val.len())),
            ("uniform", side(&uniform, uniform_ms)),
            ("mixed", side(&mixed, mixed_ms)),
            ("mixed_points", Json::from(mixed_points)),
            ("reload_recompile_ms", Json::Num(reload_ms)),
            ("points", Json::Arr(frontier)),
        ],
    );
    write_json("BENCH_menu.json", &doc).expect("write BENCH_menu.json");
    println!("wrote BENCH_menu.json");
}
