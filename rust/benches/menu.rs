//! Menu-compiler benchmark: how long compiling + Pareto-pruning the
//! full power–accuracy frontier takes, how long reloading it from the
//! `menu.json` artifact takes, and how aggressively the frontier is
//! pruned.
//!
//! Emits `BENCH_menu.json` (schema `bench-menu/v1`: compile/reload
//! wall-clock, candidates swept, points kept vs pruned, plus the
//! frontier itself) so later PRs can track the menu-compilation
//! trajectory without parsing stdout — the compile-time counterpart of
//! `BENCH_engine.json` / `BENCH_coordinator.json`.

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::data::{synth, Dataset};
use pann::nn::eval::batch_tensor;
use pann::nn::Model;
use pann::pann::{compile_menu, MenuArtifact};
use pann::quant::ActQuantMethod;
use pann::util::bench::write_json;
use pann::util::Json;
use std::time::Instant;

fn main() {
    let mut model = Model::reference_cnn(1);
    let ds = Dataset::from_synth(synth::digits(256, 2));
    let stats_x = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats_x).expect("record stats");
    let val = ds.take(96);
    let budget_bits = [2u32, 4, 8];

    // --- compile: sweep all curves, evaluate, prune ---
    let t0 = Instant::now();
    let menu = compile_menu(&model, &budget_bits, ActQuantMethod::BnStats, None, &val, 2..=8)
        .expect("compile menu");
    let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "compile-menu (bits {budget_bits:?}, {} val samples): {compile_ms:.1} ms — swept {}, \
         kept {}, pruned {}",
        val.len(),
        menu.swept,
        menu.points.len(),
        menu.pruned()
    );
    for line in menu.frontier_lines() {
        println!("  {line}");
    }

    // --- artifact round trip: save, load, recompile for serving ---
    let dir = std::env::temp_dir().join("pann_bench_menu");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("menu.json");
    menu.save(&path).expect("save menu");
    let t1 = Instant::now();
    let loaded = MenuArtifact::load(&path).expect("load menu");
    let points = loaded.shared_points(&model, None, 16).expect("recompile menu");
    let reload_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert_eq!(points.len(), menu.points.len());
    println!("reload + recompile from {}: {reload_ms:.1} ms", path.display());

    let frontier: Vec<Json> = menu
        .points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("name", Json::from(p.name.as_str())),
                ("bx_tilde", Json::from(p.bx_tilde as usize)),
                ("r", Json::Num(p.r)),
                ("gflips_per_sample", Json::Num(p.gflips_per_sample)),
                ("val_acc", Json::Num(p.val_acc)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::from("bench-menu/v1")),
        ("budget_bits", Json::nums(budget_bits.iter().map(|&b| b as f64))),
        ("val_samples", Json::from(val.len())),
        ("compile_ms", Json::Num(compile_ms)),
        ("reload_recompile_ms", Json::Num(reload_ms)),
        ("swept", Json::from(menu.swept)),
        ("kept", Json::from(menu.points.len())),
        ("pruned", Json::from(menu.pruned())),
        ("points", Json::Arr(frontier)),
    ]);
    write_json("BENCH_menu.json", &doc).expect("write BENCH_menu.json");
    println!("wrote BENCH_menu.json");
}
