//! `cargo bench --bench tables` — regenerate every paper table/figure.
//!
//! Uses full sample counts when artifacts exist; pass PANN_QUICK=1 for
//! the fast variant. Output lines mirror the paper's rows (see
//! EXPERIMENTS.md for the paper-vs-measured comparison).

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::experiments::{self, Ctx};

fn main() {
    let quick = std::env::var("PANN_QUICK").is_ok();
    let ctx = Ctx { quick, ..Ctx::default() };
    let t0 = std::time::Instant::now();
    for (name, _) in experiments::ALL {
        let t = std::time::Instant::now();
        match experiments::run(name, &ctx) {
            Ok(()) => println!("[{name} done in {:.1}s]\n", t.elapsed().as_secs_f64()),
            Err(e) => println!("[{name} skipped: {e}]\n"),
        }
    }
    println!("all tables/figures in {:.1}s", t0.elapsed().as_secs_f64());
}
