//! Scenario-harness benchmark: how fast the virtual-clock rig replays
//! each workload family on each device profile, with the rig's own
//! correctness guarantees asserted along the way (accounting
//! invariants per run, byte-identical reports across repeat runs).
//!
//! Emits `BENCH_scenarios.json` (schema `bench-scenarios/v1`): one
//! record per family x device with replay wall time, replay rate and
//! the deterministic outcome counters (served / shed / expired,
//! governor switches and windows), plus the measured determinism
//! check. Wall-time fields are machine-dependent; the outcome
//! counters are pure functions of (trace, config) and reproduce
//! anywhere.

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use pann::scenario::{
    replay, DeviceProfile, FrontierPoint, ReplayConfig, Trace, TraceFamily, TraceParams,
};
use pann::util::bench::{stamped, write_json};
use pann::util::Json;
use std::time::Instant;

const EVENTS: usize = 2048;
const SHARDS: usize = 2;

/// Synthetic three-point frontier (costs in Gflips/sample) — fixed
/// here rather than compiled from a model so the outcome counters in
/// the artifact are comparable across machines.
fn frontier() -> Vec<FrontierPoint> {
    vec![
        FrontierPoint { name: "cheap".into(), cost_gflips: 0.02, acc_proxy: 0.90 },
        FrontierPoint { name: "mid".into(), cost_gflips: 0.08, acc_proxy: 0.95 },
        FrontierPoint { name: "rich".into(), cost_gflips: 0.32, acc_proxy: 0.985 },
    ]
}

fn main() {
    let params = TraceParams { seed: 7, events: EVENTS, duration_us: 2_000_000, tenants: 4 };
    let mut runs = Vec::new();
    for device in DeviceProfile::all() {
        for family in TraceFamily::ALL {
            let trace = Trace::generate(family, &params);
            let mut cfg = ReplayConfig::new(device);
            cfg.shards = SHARDS;
            let t0 = Instant::now();
            let report = replay(&trace, &frontier(), &cfg).expect("replay");
            let secs = t0.elapsed().as_secs_f64();
            assert!(report.invariants().is_empty(), "{:?}", report.invariants());
            let switches: u64 = report.governors.iter().map(|g| g.switches).sum();
            let windows: u64 = report.governors.iter().map(|g| g.windows).sum();
            println!(
                "{:<12} on {:<7}: {} events in {:>7.2} ms ({:>9.0} ev/s) \
                 served {} shed {} expired {} switches {}",
                family.name(),
                device.name,
                EVENTS,
                secs * 1e3,
                EVENTS as f64 / secs.max(1e-9),
                report.totals.served,
                report.totals.shed,
                report.totals.expired,
                switches,
            );
            runs.push(Json::obj(vec![
                ("family", Json::from(family.name())),
                ("device", Json::from(device.name)),
                ("events", Json::from(EVENTS)),
                ("replay_ms", Json::Num(secs * 1e3)),
                ("events_per_sec", Json::Num(EVENTS as f64 / secs.max(1e-9))),
                ("served", Json::Num(report.totals.served as f64)),
                ("shed", Json::Num(report.totals.shed as f64)),
                ("expired", Json::Num(report.totals.expired as f64)),
                ("governor_switches", Json::Num(switches as f64)),
                ("governor_windows", Json::Num(windows as f64)),
            ]));
        }
    }

    // the harness's core promise, measured end to end: two replays of
    // the same trace serialize byte-identically
    let trace = Trace::generate(TraceFamily::FlashCrowd, &params);
    let cfg = ReplayConfig::new(DeviceProfile::server());
    let a = replay(&trace, &frontier(), &cfg).expect("replay").to_json().to_string();
    let b = replay(&trace, &frontier(), &cfg).expect("replay").to_json().to_string();
    assert_eq!(a, b, "replay must be byte-deterministic");
    println!("determinism: two replays -> identical {}-byte reports", a.len());

    let doc = stamped(
        "bench-scenarios/v1",
        "committed baseline captured on an 8-core x86-64 dev box (cargo bench --bench \
         scenarios, release profile); replay_ms / events_per_sec are machine-dependent — \
         served/shed/expired and the governor counters are deterministic functions of \
         (trace, config) and must reproduce exactly on any machine",
        vec![
            ("trace_events", Json::from(EVENTS)),
            ("shards", Json::from(SHARDS)),
            ("seed", Json::from(params.seed as usize)),
            ("runs", Json::Arr(runs)),
            (
                "determinism",
                Json::obj(vec![
                    ("byte_identical", Json::from(true)),
                    ("report_bytes", Json::from(a.len())),
                ]),
            ),
        ],
    );
    write_json("BENCH_scenarios.json", &doc).expect("write BENCH_scenarios.json");
    println!("wrote BENCH_scenarios.json");
}
