//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//! Two menus, picked automatically, behind the *same* `ServerBuilder`
//! entry point and `Client`:
//!
//! - **PJRT** (requires `make artifacts` and a `--features pjrt`
//!   build): JAX+Pallas AOT artifacts (L1+L2) are loaded by the Rust
//!   PJRT runtime and served via `Menu::local` — PJRT executables are
//!   not `Send`, so the menu is built on the single worker thread.
//! - **Native pool** (default, no artifacts needed): the built-in
//!   reference CNN is compiled into one immutable `ExecutionPlan` per
//!   operating point and served via `Menu::shared` by a pool of
//!   workers with per-worker scratch arenas.
//!
//! Either way the driver replays a test set as a request stream,
//! *changes the energy budget at runtime* (the paper's deployment
//! claim), then demonstrates the per-request QoS surface: two
//! simultaneous clients with different `max_gflips` caps served by
//! different operating points, and an over-deadline request rejected
//! with a typed `ServeError::DeadlineExceeded` — unexecuted.
//!
//! ```sh
//! cargo run --release --example serve_e2e
//! ```

use pann::coordinator::{
    EnginePoint, InferRequest, Menu, PlanEngine, Priority, ServeError, Server, ServerBuilder,
    SharedPoint,
};
use pann::data::Dataset;
use pann::nn::eval::batch_tensor;
use pann::nn::quantized::{QuantConfig, QuantizedModel};
use pann::nn::Model;
use pann::quant::ActQuantMethod;
use pann::runtime::{ArtifactManifest, CpuRuntime};
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "cnn-s".to_string());
    let artifacts = std::path::PathBuf::from("artifacts");
    // PJRT needs both the artifacts and a `--features pjrt` build (the
    // default build has a stub runtime whose constructor errors); any
    // PJRT-path failure falls back to the native pool.
    match ArtifactManifest::load(&artifacts.join("hlo")) {
        Ok(manifest) => match serve_pjrt(&model, &artifacts, manifest) {
            Ok(()) => Ok(()),
            Err(e) => {
                eprintln!("PJRT serving unavailable ({e:#}); serving the native engine pool instead");
                serve_native_pool()
            }
        },
        Err(e) => {
            eprintln!("no PJRT artifacts ({e:#}); serving the native engine pool instead");
            serve_native_pool()
        }
    }
}

/// Single-worker PJRT serving over AOT artifacts (`Menu::local`: the
/// executables are built on, and never leave, the worker thread).
fn serve_pjrt(
    model: &str,
    artifacts: &std::path::Path,
    manifest: ArtifactManifest,
) -> anyhow::Result<()> {
    let specs: Vec<_> = manifest.points_for(model).into_iter().cloned().collect();
    anyhow::ensure!(!specs.is_empty(), "no executables for {model}");

    let srv = ServerBuilder::new()
        .max_batch(8)
        .max_wait(Duration::from_millis(1))
        .queue_depth(512)
        .serve(Menu::local(move || {
            let rt = CpuRuntime::new()?;
            eprintln!("PJRT platform: {}", rt.platform());
            let mut points = Vec::new();
            for spec in &specs {
                let lm = rt.load(&spec.file, &spec.input_shape)?;
                eprintln!(
                    "  loaded {:<12} ({:.5} Gflips/sample)",
                    spec.variant, spec.giga_flips_per_sample
                );
                points.push(EnginePoint {
                    name: spec.variant.clone(),
                    giga_flips_per_sample: if spec.variant == "fp32" {
                        f64::INFINITY
                    } else {
                        spec.giga_flips_per_sample
                    },
                    engine: Box::new(lm),
                });
            }
            Ok(points)
        }))?;

    let ds_name = pann::experiments::dataset_for(model);
    let ds = Dataset::load(&artifacts.join("data").join(ds_name), "test")?;
    let macs = pann::experiments::qat::num_macs(model) as f64;
    let header = format!("serving {model} over {ds_name} (PJRT, 1 worker)");
    run_phases(srv, &ds, macs, &header)
}

/// Worker-pool serving of the built-in reference CNN: one
/// `Arc<ExecutionPlan>` per operating point, shared by every worker
/// (`Menu::shared`).
fn serve_native_pool() -> anyhow::Result<()> {
    let mut model = Model::reference_cnn(5);
    let ds = Dataset::from_synth(pann::data::synth::digits(512, 6));
    let stats = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats)?;

    let max_batch = 16;
    let mut points = Vec::new();
    for (bits, bx, r) in [(2u32, 6u32, 10.0 / 6.0 - 0.5), (4, 7, 24.0 / 7.0 - 0.5), (8, 8, 7.5)] {
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::pann(bx, r, ActQuantMethod::BnStats),
            None,
        )?;
        let gf = pann::power::model::mac_power_unsigned_total(bits) * qm.macs_per_sample as f64 / 1e9;
        eprintln!("  compiled pann-p{bits} ({gf:.5} Gflips/sample)");
        points.push(SharedPoint {
            name: format!("pann-p{bits}"),
            giga_flips_per_sample: gf,
            engine: Arc::new(PlanEngine::new(qm.plan(), max_batch)),
        });
    }
    let n_workers = pann::nn::eval::n_threads();
    let srv = ServerBuilder::new()
        .workers(n_workers)
        .max_batch(max_batch)
        .max_wait(Duration::from_millis(1))
        .queue_depth(1024)
        .serve(Menu::shared(points))?;
    let macs = model.num_macs() as f64;
    let header = format!("serving ref-cnn over synth digits (native pool, {n_workers} workers)");
    run_phases(srv, &ds, macs, &header)
}

/// Replay the test set through three budget phases, then exercise the
/// per-request QoS surface, and report.
fn run_phases(srv: Server, ds: &Dataset, macs: f64, header: &str) -> anyhow::Result<()> {
    let client = srv.client();
    let n_phase = 256.min(ds.len());
    // Three budget phases: unlimited, generous (8-bit PANN budget),
    // tight (2-bit budget). The menu never reloads — only the (b̃x, R)
    // operating point changes, the paper's deployment claim.
    let phases = [
        ("unlimited", f64::INFINITY),
        ("8-bit budget", 64.0 * macs / 1e9),
        ("2-bit budget", 10.0 * macs / 1e9),
    ];
    println!("\n{header}, {n_phase} requests per phase");
    let clients = 4usize;
    for (label, budget) in phases {
        client.set_budget(budget);
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| -> anyhow::Result<()> {
            let mut js = Vec::new();
            for c in 0..clients {
                let client = client.clone();
                js.push(s.spawn(move || -> Result<(usize, String), ServeError> {
                    let mut ok = 0;
                    let mut point = String::new();
                    for i in (c..n_phase).step_by(clients) {
                        let r = client.infer(ds.sample(i).to_vec())?;
                        let pred = r
                            .output
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .map(|(j, _)| j)
                            .unwrap_or(0);
                        if pred == ds.y[i] as usize {
                            ok += 1;
                        }
                        point = r.point;
                    }
                    Ok((ok, point))
                }));
            }
            let mut total = 0;
            let mut point = String::new();
            for j in js {
                let (ok, p) = j.join().expect("client panicked")?;
                total += ok;
                point = p;
            }
            println!(
                "  phase {label:<14} -> point {point:<10} accuracy {:.3}  ({:.2}s)",
                total as f64 / n_phase as f64,
                t0.elapsed().as_secs_f64()
            );
            Ok(())
        })?;
    }

    // --- per-request QoS: two caps, two points, one server ---
    client.set_budget(f64::INFINITY);
    let tight_cap = 12.0 * macs / 1e9; // ~2-bit equal-power budget
    let hi = client.submit(
        InferRequest::new(ds.sample(0).to_vec())
            .priority(Priority::Hi)
            .tag("uncapped"),
    )?;
    let capped = client.submit(
        InferRequest::new(ds.sample(1).to_vec())
            .max_gflips(tight_cap)
            .tag("capped"),
    )?;
    let expired = client
        .submit(InferRequest::new(ds.sample(2).to_vec()).deadline(Duration::ZERO))?
        .wait();
    let hi = hi.wait()?;
    let capped = capped.wait()?;
    println!("\nper-request QoS (global budget unlimited):");
    println!("  {:<10} -> point {}", hi.tag.as_deref().unwrap_or(""), hi.point);
    println!("  {:<10} -> point {}", capped.tag.as_deref().unwrap_or(""), capped.point);
    match expired {
        Err(ServeError::DeadlineExceeded) => {
            println!("  over-deadline request rejected unexecuted: deadline exceeded")
        }
        other => println!("  over-deadline request unexpectedly: {other:?}"),
    }

    println!("\n{}", client.metrics().report());
    srv.shutdown();
    Ok(())
}
