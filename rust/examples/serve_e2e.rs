//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//! Two menus, picked automatically, behind the *same* `ServerBuilder`
//! entry point and `Client`:
//!
//! - **PJRT** (requires `make artifacts` and a `--features pjrt`
//!   build): JAX+Pallas AOT artifacts (L1+L2) are loaded by the Rust
//!   PJRT runtime and served via `Menu::local` — PJRT executables are
//!   not `Send`, so the menu is built on the single worker thread.
//! - **Native pool** (default, no artifacts needed): the operating-
//!   point menu is *compiled* — `pann::pann::compile_menu` sweeps the
//!   2/4/8-bit equal-power curves over the built-in reference CNN,
//!   Pareto-prunes to the accuracy-vs-energy frontier, persists it as
//!   a `menu.json` artifact, and `Menu::from_artifact` reloads and
//!   recompiles it for a pool of workers — the full
//!   `compile-menu → serve --menu` round trip in one process.
//!
//! Either way the driver replays a test set as a request stream,
//! *changes the energy budget at runtime* (the paper's deployment
//! claim), then demonstrates the per-request QoS surface: two
//! simultaneous clients with different `max_gflips` caps served by
//! different operating points, and an over-deadline request rejected
//! with a typed `ServeError::DeadlineExceeded` — unexecuted.
//!
//! ```sh
//! cargo run --release --example serve_e2e
//! ```

use pann::coordinator::{
    EnginePoint, InferRequest, Menu, Priority, ServeError, Server, ServerBuilder,
};
use pann::data::Dataset;
use pann::nn::eval::batch_tensor;
use pann::nn::Model;
use pann::quant::ActQuantMethod;
use pann::runtime::{ArtifactManifest, CpuRuntime};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "cnn-s".to_string());
    let artifacts = std::path::PathBuf::from("artifacts");
    // PJRT needs both the artifacts and a `--features pjrt` build (the
    // default build has a stub runtime whose constructor errors); any
    // PJRT-path failure falls back to the native pool.
    match ArtifactManifest::load(&artifacts.join("hlo")) {
        Ok(manifest) => match serve_pjrt(&model, &artifacts, manifest) {
            Ok(()) => Ok(()),
            Err(e) => {
                eprintln!("PJRT serving unavailable ({e:#}); serving the native engine pool instead");
                serve_native_pool()
            }
        },
        Err(e) => {
            eprintln!("no PJRT artifacts ({e:#}); serving the native engine pool instead");
            serve_native_pool()
        }
    }
}

/// Single-worker PJRT serving over AOT artifacts (`Menu::local`: the
/// executables are built on, and never leave, the worker thread).
fn serve_pjrt(
    model: &str,
    artifacts: &std::path::Path,
    manifest: ArtifactManifest,
) -> anyhow::Result<()> {
    let specs: Vec<_> = manifest.points_for(model).into_iter().cloned().collect();
    anyhow::ensure!(!specs.is_empty(), "no executables for {model}");

    let srv = ServerBuilder::new()
        .max_batch(8)
        .max_wait(Duration::from_millis(1))
        .queue_depth(512)
        .serve(Menu::local(move || {
            let rt = CpuRuntime::new()?;
            eprintln!("PJRT platform: {}", rt.platform());
            let mut points = Vec::new();
            for spec in &specs {
                let lm = rt.load(&spec.file, &spec.input_shape)?;
                eprintln!(
                    "  loaded {:<12} ({:.5} Gflips/sample)",
                    spec.variant, spec.giga_flips_per_sample
                );
                points.push(EnginePoint {
                    name: spec.variant.clone(),
                    giga_flips_per_sample: if spec.variant == "fp32" {
                        f64::INFINITY
                    } else {
                        spec.giga_flips_per_sample
                    },
                    engine: Box::new(lm),
                });
            }
            Ok(points)
        }))?;

    let ds_name = pann::experiments::dataset_for(model);
    let ds = Dataset::load(&artifacts.join("data").join(ds_name), "test")?;
    let macs = pann::experiments::qat::num_macs(model) as f64;
    // Three budget phases: unlimited, generous (8-bit PANN budget),
    // tight (2-bit budget).
    let phases = vec![
        ("unlimited".to_string(), f64::INFINITY),
        ("8-bit budget".to_string(), 64.0 * macs / 1e9),
        ("2-bit budget".to_string(), 10.0 * macs / 1e9),
    ];
    let header = format!("serving {model} over {ds_name} (PJRT, 1 worker)");
    run_phases(srv, &ds, &phases, &header)
}

/// Worker-pool serving of the built-in reference CNN over a *compiled*
/// menu: sweep → Pareto-prune → `menu.json` → `Menu::from_artifact`.
fn serve_native_pool() -> anyhow::Result<()> {
    let mut model = Model::reference_cnn(5);
    let ds = Dataset::from_synth(pann::data::synth::digits(512, 6));
    let stats = batch_tensor(&ds, 0, 64);
    model.record_act_stats(&stats)?;

    // compile the frontier on a validation slice and persist it
    let val = ds.take(128);
    let compiled =
        pann::pann::compile_menu(&model, &[2, 4, 8], ActQuantMethod::BnStats, None, &val, 2..=8)?;
    let dir = std::env::temp_dir().join("pann_serve_e2e");
    std::fs::create_dir_all(&dir)?;
    let menu_path = dir.join("menu.json");
    compiled.save(&menu_path)?;
    eprintln!(
        "compiled menu: swept {} candidates, kept {} frontier points ({} pruned) -> {}",
        compiled.swept,
        compiled.points.len(),
        compiled.pruned(),
        menu_path.display()
    );
    for line in compiled.frontier_lines() {
        eprintln!("  {line}");
    }

    // reload through the artifact path — exactly what
    // `pann-cli serve --menu menu.json` does (the engines are built
    // inside serve() with the builder's max_batch)
    let menu = Menu::from_artifact(&menu_path, &model)?;
    let n_workers = pann::nn::eval::n_threads();
    let srv = ServerBuilder::new()
        .workers(n_workers)
        .max_batch(16)
        .max_wait(Duration::from_millis(1))
        .queue_depth(1024)
        .serve(menu)?;
    // one budget phase per frontier point (cheapest first), then
    // unlimited: deployment-time traversal across the whole menu
    let mut phases: Vec<(String, f64)> = compiled
        .points
        .iter()
        .map(|p| (p.name.clone(), p.gflips_per_sample * (1.0 + 1e-9)))
        .collect();
    phases.push(("unlimited".to_string(), f64::INFINITY));
    let header = format!(
        "serving ref-cnn over synth digits (native pool, {n_workers} workers, compiled menu)"
    );
    run_phases(srv, &ds, &phases, &header)
}

/// Replay the test set through the given budget phases, then exercise
/// the per-request QoS surface, and report.
fn run_phases(
    srv: Server,
    ds: &Dataset,
    phases: &[(String, f64)],
    header: &str,
) -> anyhow::Result<()> {
    let client = srv.client();
    let n_phase = 256.min(ds.len());
    println!("\n{header}, {n_phase} requests per phase");
    let clients = 4usize;
    for (label, budget) in phases {
        client.set_budget(*budget);
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| -> anyhow::Result<()> {
            let mut js = Vec::new();
            for c in 0..clients {
                let client = client.clone();
                js.push(s.spawn(move || -> Result<(usize, String), ServeError> {
                    let mut ok = 0;
                    let mut point = String::new();
                    for i in (c..n_phase).step_by(clients) {
                        let r = client.infer(ds.sample(i).to_vec())?;
                        let pred = r
                            .output
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .map(|(j, _)| j)
                            .unwrap_or(0);
                        if pred == ds.y[i] as usize {
                            ok += 1;
                        }
                        point = r.point;
                    }
                    Ok((ok, point))
                }));
            }
            let mut total = 0;
            let mut point = String::new();
            for j in js {
                let (ok, p) = j.join().expect("client panicked")?;
                total += ok;
                point = p;
            }
            println!(
                "  phase {label:<20} -> point {point:<18} accuracy {:.3}  ({:.2}s)",
                total as f64 / n_phase as f64,
                t0.elapsed().as_secs_f64()
            );
            Ok(())
        })?;
    }

    // --- per-request QoS: two caps, two points, one server ---
    client.set_budget(f64::INFINITY);
    // tightest finite phase budget = the cheapest point's cap
    let tight_cap = phases
        .iter()
        .map(|(_, b)| *b)
        .filter(|b| b.is_finite())
        .fold(f64::INFINITY, f64::min);
    let hi = client.submit(
        InferRequest::new(ds.sample(0).to_vec())
            .priority(Priority::Hi)
            .tag("uncapped"),
    )?;
    let capped = client.submit(
        InferRequest::new(ds.sample(1).to_vec())
            .max_gflips(tight_cap)
            .tag("capped"),
    )?;
    let expired = client
        .submit(InferRequest::new(ds.sample(2).to_vec()).deadline(Duration::ZERO))?
        .wait();
    let hi = hi.wait()?;
    let capped = capped.wait()?;
    println!("\nper-request QoS (global budget unlimited):");
    println!("  {:<10} -> point {}", hi.tag.as_deref().unwrap_or(""), hi.point);
    println!("  {:<10} -> point {}", capped.tag.as_deref().unwrap_or(""), capped.point);
    match expired {
        Err(ServeError::DeadlineExceeded) => {
            println!("  over-deadline request rejected unexecuted: deadline exceeded")
        }
        other => println!("  over-deadline request unexpectedly: {other:?}"),
    }

    println!("\n{}", client.metrics().report());
    srv.shutdown();
    Ok(())
}
