//! Quickstart: convert a model to PANN and compare against the
//! quantized baseline at the same power budget.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Uses trained artifacts when present (`make artifacts`), otherwise
//! the built-in reference CNN on synthetic digits.

use pann::experiments::Ctx;
use pann::pann::{algorithm1, convert};
use pann::power::model::mac_power_unsigned_total;
use pann::quant::ActQuantMethod;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::default();
    let (model, test) = ctx.load_model("cnn-s")?;
    let test = test.take(512);
    let calib = convert::calib_tensor(&test, 32);

    println!("model: {} ({} MACs/sample)", model.name, model.num_macs());
    let fp = pann::nn::eval::eval_fp32(&model, &test)?;
    println!("fp32 accuracy: {:.3}\n", fp.accuracy());

    // A 2-bit power budget: where conventional PTQ collapses.
    let bits = 2;
    let budget = mac_power_unsigned_total(bits);
    println!("power budget: {budget} flips/MAC (a {bits}-bit unsigned MAC)");

    // 1) conventional quantized baseline at that budget
    let (_, base) = convert::unsigned_of(&model, bits, ActQuantMethod::Aciq, Some(&calib), &test)?;
    println!(
        "baseline  {bits}-bit unsigned MAC: accuracy {:.3}  ({:.4} Gflips total)",
        base.accuracy(),
        base.giga_flips
    );

    // 2) PANN at the *same* budget, operating point from Algorithm 1
    let op = algorithm1::choose_operating_point(
        &model,
        budget,
        ActQuantMethod::Aciq,
        Some(&calib),
        &test.take(128),
        2..=8,
    )?;
    println!("Algorithm 1 chose b̃x = {}, R = {:.2}", op.bx_tilde, op.r);
    let (qm, ours) =
        convert::pann_at_budget(&model, op.bx_tilde, op.r, ActQuantMethod::Aciq, Some(&calib), &test)?;
    println!(
        "PANN (multiplier-free):     accuracy {:.3}  ({:.4} Gflips total, achieved R {:.2})",
        ours.accuracy(),
        ours.giga_flips,
        qm.achieved_r()
    );
    println!(
        "\nsame power, Δaccuracy = {:+.3} — the paper's headline effect (Table 2, 2-bit row)",
        ours.accuracy() - base.accuracy()
    );
    Ok(())
}
