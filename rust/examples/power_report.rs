//! Power-model walkthrough: regenerate the paper's measurement story
//! (Table 1, Observations 1 & 2, the unsigned save, Eq. 13) from the
//! toggle simulators and analytic models.
//!
//! ```sh
//! cargo run --release --example power_report
//! ```

use pann::bitflip::{BoothMultiplier, Dist, MacUnit, Sampler};
use pann::power::model::*;
use pann::util::Rng;

fn main() {
    let n = 20_000;
    println!("== measured toggles per signed MAC (B = 32) vs the paper's model ==");
    println!("{:<4} {:>12} {:>12} {:>12} {:>10}", "b", "measured", "model", "acc-input", "0.5B");
    for b in [2u32, 4, 6, 8] {
        let mut mac = MacUnit::new(BoothMultiplier::new(b, true), 32);
        let mut rng = Rng::new(1);
        let mut sw = Sampler::new(Dist::UniformSigned(b), n, &mut rng);
        let mut sx = Sampler::new(Dist::UniformSigned(b), n, &mut rng);
        let (mut total, mut acc_in) = (0u64, 0u64);
        for i in 0..n {
            if i % 256 == 0 {
                mac.clear_acc();
            }
            let t = mac.mac(sw.next(), sx.next());
            total += t.paper_total();
            acc_in += t.acc_input;
        }
        let model = mac_power_signed(b, 32).total();
        println!(
            "{b:<4} {:>12.1} {:>12.1} {:>12.1} {:>10.1}",
            total as f64 / n as f64,
            model,
            acc_in as f64 / n as f64,
            16.0
        );
    }

    println!("\n== Observation 1: switching to unsigned arithmetic ==");
    for b in [2u32, 4, 8] {
        let s = mac_power_signed(b, 32).total();
        let u = mac_power_unsigned(b).total();
        println!("b={b}: signed {s:>5.1} -> unsigned {u:>5.1} flips/MAC  (save {:.0}%)", 100.0 * (1.0 - u / s));
    }

    println!("\n== Observation 2: the multiplier ignores the smaller width ==");
    for bw in [2u32, 4, 8] {
        println!("bw={bw}, bx=8: P_mult = {:.1} flips", mult_power_mixed_signed(bw, 8));
    }

    println!("\n== PANN (Eq. 13): equal-power menu of a 4-bit unsigned MAC ==");
    let p = mac_power_unsigned_total(4);
    for bt in 2..=8u32 {
        if let Some(r) = pann::power::budget::equal_power_r(p, bt) {
            if r > 0.0 {
                println!("b̃x={bt}: R={r:.2} additions/element -> {:.1} flips", pann_power_per_element(r, bt));
            }
        }
    }
}
