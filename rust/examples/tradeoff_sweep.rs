//! Fig. 1-style power–accuracy sweep plus the Table 15 trade-off menu:
//! signed → unsigned → PANN arrows at several budgets, then the whole
//! 2-bit equal-power curve.
//!
//! ```sh
//! cargo run --release --example tradeoff_sweep
//! ```

use pann::experiments::Ctx;
use pann::nn::quantized::Arithmetic;
use pann::pann::{algorithm1, convert, tradeoff};
use pann::power::model::mac_power_unsigned_total;
use pann::quant::ActQuantMethod;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::default();
    let (model, test) = ctx.load_model("cnn-s")?;
    let test = test.take(384);
    let calib = convert::calib_tensor(&test, 32);

    println!("== Fig. 1 arrows (per-sample Mflips, accuracy) ==");
    for bits in [2u32, 4] {
        let (_, signed) = convert::ptq_baseline(
            &model,
            bits,
            ActQuantMethod::Aciq,
            Arithmetic::SignedMac { acc_bits: 32 },
            Some(&calib),
            &test,
        )?;
        let (_, unsigned) =
            convert::unsigned_of(&model, bits, ActQuantMethod::Aciq, Some(&calib), &test)?;
        let p = mac_power_unsigned_total(bits);
        let op = algorithm1::choose_operating_point(
            &model,
            p,
            ActQuantMethod::Aciq,
            Some(&calib),
            &test.take(96),
            2..=8,
        )?;
        let (_, ours) =
            convert::pann_at_budget(&model, op.bx_tilde, op.r, ActQuantMethod::Aciq, Some(&calib), &test)?;
        let per = |g: f64| 1000.0 * g / test.len() as f64;
        println!(
            "{bits}-bit: signed ({:.3}, {:.3}) --left--> unsigned ({:.3}, {:.3}) --up--> PANN ({:.3}, {:.3})",
            per(signed.giga_flips),
            signed.accuracy(),
            per(unsigned.giga_flips),
            unsigned.accuracy(),
            per(ours.giga_flips),
            ours.accuracy(),
        );
    }

    println!("\n== Table 15: the 2-bit equal-power curve ==");
    let rows = tradeoff::budget_curve_table(&model, 2, ActQuantMethod::Aciq, Some(&calib), &test, 2..=8)?;
    println!(
        "{:<5} {:>10} {:>5} {:>9} {:>9} {:>9}",
        "b̃x", "R(=lat)", "b_R", "act-mem", "w-mem", "accuracy"
    );
    for r in rows {
        println!(
            "{:<5} {:>10.2} {:>5} {:>9.2} {:>9.2} {:>9.3}",
            r.bx_tilde, r.r, r.b_r, r.act_mem_factor, r.weight_mem_factor, r.accuracy
        );
    }
    Ok(())
}
